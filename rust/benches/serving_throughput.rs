//! Serving-layer bench: aggregate tokens/s and p50/p95 request latency
//! vs. engine-pool size and exit threshold — the Figure 8 axes
//! (quality/latency vs. threshold) lifted to the multi-request setting of
//! the serving front-end.
//!
//! Shape checks: pool size > 1 must out-throughput pool size 1 on the
//! same request set (that is the point of the pool), the aggregate
//! early-exit fraction must grow as the threshold drops, and on the
//! shared-system-prompt workload the prefix KV cache must score hits and
//! save prefill positions without changing a single generated token.
//! The tiered-store section requires the device tier to promote hot
//! prefixes and serve device hits — again without changing tokens — and
//! the conversational section requires every follow-up turn to restore
//! its end-of-turn snapshot, so the positions actually prefilled at turn
//! N are O(that turn's new text), with warm and cold-replay token
//! streams identical on both engines.

#[path = "bench_util.rs"]
mod bench_util;

use eellm::data::synth::{
    bursty_traffic, conversation_traffic, shared_prefix_prompts, ConvoSpec,
    SharedPrefixSpec, TrafficSpec,
};
use eellm::data::tasks;
use eellm::inference::{ExitPolicy, TierStats};
use eellm::serve::{
    requests_from_tasks, ControlConfig, ConvoStats, EngineKind, EnginePool,
    Policy, PoolConfig, ServeRequest, ShedPolicy,
};
use eellm::util::table::Table;

fn main() {
    let steps = if bench_util::fast() { 60 } else { 200 };
    let Some(state) = bench_util::trained_state("ee-tiny", steps) else {
        return;
    };
    let n_layers = state.man.model.n_layers;
    let corpus = bench_util::corpus();
    let n_req = if bench_util::fast() { 8 } else { 24 };
    let suite = tasks::all_tasks(&corpus, n_req, 5);
    let reqs = requests_from_tasks(&suite, n_req, state.man.model.max_seq);

    let pool_sizes = [1usize, 2, 4];
    let thresholds = [1.0f32, 0.6, 0.2];
    let mut table = Table::new(
        "Serving throughput vs pool size and exit threshold",
        &["pool", "threshold", "tok/s", "p50 latency", "p95 latency",
          "p50 TTFT", "p95 TTFT", "p50 tok gap", "early%"],
    );

    // Mean throughput per pool size (over thresholds) for the scaling
    // check, and early fraction per threshold at the largest pool.
    let mut tput = vec![0f64; pool_sizes.len()];
    let mut early = vec![0f64; thresholds.len()];
    for (pi, &workers) in pool_sizes.iter().enumerate() {
        for (ti, &tau) in thresholds.iter().enumerate() {
            let mut pool = EnginePool::new(
                state.clone(),
                PoolConfig {
                    workers,
                    engine: EngineKind::Sequential,
                    policy: ExitPolicy::confidence(tau),
                    sched: Policy::ShortestPromptFirst,
                    max_concurrent: 4,
                    prefix_cache_positions: 0,
                    device_tier_positions: 0,
                    convo_idle_ttl: std::time::Duration::from_secs(300),
                    // Lanes off here: this section measures worker-pool
                    // scaling alone; the lanes-on/off comparison below
                    // isolates fusion.
                    lane_fusion: false,
                    lane_residency: true,
                    control: ControlConfig::default(),
                },
            );
            let out = pool.run_batch(reqs.clone()).expect("batch");
            pool.shutdown().expect("shutdown");
            assert!(
                out.failures.is_empty(),
                "requests failed: {:?}",
                out.failures
            );
            let m = &out.metrics;
            tput[pi] += m.throughput_tps() / thresholds.len() as f64;
            if workers == *pool_sizes.last().unwrap() {
                early[ti] = m.early_fraction(n_layers);
            }
            // TTFT must be a lower bound on full-request latency.
            assert!(m.p50_ttft_seconds <= m.p50_latency_seconds + 1e-9);
            table.row(vec![
                format!("{workers}"),
                format!("{tau}"),
                format!("{:.1}", m.throughput_tps()),
                format!("{:.0}ms", m.p50_latency_seconds * 1e3),
                format!("{:.0}ms", m.p95_latency_seconds * 1e3),
                format!("{:.0}ms", m.p50_ttft_seconds * 1e3),
                format!("{:.0}ms", m.p95_ttft_seconds * 1e3),
                format!("{:.1}ms", m.p50_token_gap_seconds * 1e3),
                format!("{:.0}%", 100.0 * m.early_fraction(n_layers)),
            ]);
        }
    }
    table.emit("serving_throughput");

    println!(
        "mean tok/s by pool size {pool_sizes:?}: {:?}",
        tput.iter().map(|t| format!("{t:.1}")).collect::<Vec<_>>()
    );
    let best_pooled = tput[1..].iter().cloned().fold(f64::MIN, f64::max);
    assert!(
        best_pooled > tput[0],
        "pooling yields no throughput gain over a single worker: {tput:?}"
    );
    assert!(
        early.last().unwrap() >= early.first().unwrap(),
        "early-exit fraction did not grow as the threshold dropped: {early:?}"
    );

    // --- Prefix KV-cache reuse on a shared-system-prompt workload ---
    // Shape checks: outputs are byte-identical with the cache on vs off,
    // and the cached run actually restores prefixes (nonzero hits and
    // prefill positions saved).
    let max_seq = state.man.model.max_seq;
    let spec = SharedPrefixSpec {
        seed: 11,
        n_groups: 2,
        requests_per_group: if bench_util::fast() { 3 } else { 6 },
        prefix_bytes: max_seq / 2,
    };
    let prompts = shared_prefix_prompts(&spec, &corpus.facts);
    let shared_reqs: Vec<ServeRequest> = prompts
        .iter()
        .enumerate()
        .map(|(i, p)| ServeRequest::new(i as u64, p.as_str(), 8))
        .collect();
    let mut prefix_table = Table::new(
        "Prefix KV-cache reuse (shared-system-prompt workload)",
        &["cache", "tok/s", "hit rate", "prefill saved", "insert", "evict"],
    );
    let mut outputs: Vec<Vec<Vec<i32>>> = Vec::new();
    for &budget in &[0usize, 8 * max_seq] {
        let mut pool = EnginePool::new(
            state.clone(),
            PoolConfig {
                workers: 1,
                engine: EngineKind::Sequential,
                policy: ExitPolicy::confidence(0.6),
                sched: Policy::Fifo,
                max_concurrent: 4,
                prefix_cache_positions: budget,
                device_tier_positions: 0,
                convo_idle_ttl: std::time::Duration::from_secs(300),
                lane_fusion: false,
                lane_residency: true,
                control: ControlConfig::default(),
            },
        );
        let out = pool.run_batch(shared_reqs.clone()).expect("batch");
        pool.shutdown().expect("shutdown");
        assert!(out.failures.is_empty(), "{:?}", out.failures);
        let m = &out.metrics;
        prefix_table.row(vec![
            if budget == 0 { "off".into() } else { format!("{budget} pos") },
            format!("{:.1}", m.throughput_tps()),
            format!("{:.0}%", 100.0 * m.prefix_hit_rate()),
            format!("{} pos", m.prefill_positions_saved()),
            format!("{}", m.prefix.insertions),
            format!("{}", m.prefix.evictions),
        ]);
        if budget == 0 {
            assert_eq!(m.prefix.lookups(), 0, "disabled cache was consulted");
        } else {
            assert!(m.prefix.hits > 0, "no prefix hits on shared prompts");
            assert!(
                m.prefill_positions_saved() > 0,
                "prefix hits saved no prefill positions"
            );
        }
        outputs.push(
            out.responses.iter().map(|r| r.output.tokens.clone()).collect(),
        );
    }
    prefix_table.emit("serving_throughput");
    assert_eq!(
        outputs[0], outputs[1],
        "prefix cache changed generated tokens"
    );

    // --- Lane-fused batched decode: lanes-on vs lanes-off ---
    // Shape checks: tokens are byte-identical with fusion on vs off
    // (batching is output-invisible), fused lane groups actually form
    // under load (decode steps per XLA dispatch > 1 at max_concurrent
    // 4), and the throughput ratio is reported.
    let mut lane_table = Table::new(
        "Lane-fused batched decode (shared-prefix workload, \
         max_concurrent 4)",
        &["lanes", "tok/s", "steps/dispatch", "fused calls", "occupancy",
          "solo steps", "stages skipped"],
    );
    let mut lane_outputs: Vec<Vec<Vec<i32>>> = Vec::new();
    let mut lane_tput = Vec::new();
    for &fusion in &[false, true] {
        let mut pool = EnginePool::new(
            state.clone(),
            PoolConfig {
                workers: 1,
                engine: EngineKind::Sequential,
                policy: ExitPolicy::confidence(0.6),
                sched: Policy::Fifo,
                max_concurrent: 4,
                prefix_cache_positions: 0,
                device_tier_positions: 0,
                convo_idle_ttl: std::time::Duration::from_secs(300),
                lane_fusion: fusion,
                lane_residency: true,
                control: ControlConfig::default(),
            },
        );
        let out = pool.run_batch(shared_reqs.clone()).expect("batch");
        pool.shutdown().expect("shutdown");
        assert!(out.failures.is_empty(), "{:?}", out.failures);
        let m = &out.metrics;
        let l = &m.lanes;
        lane_table.row(vec![
            if fusion { "on".into() } else { "off".to_string() },
            format!("{:.1}", m.throughput_tps()),
            format!("{:.2}", l.steps_per_dispatch()),
            format!("{}", l.fused_calls),
            format!("{:?}", l.occupancy),
            format!("{}", l.solo_steps),
            format!("{}", l.stages_skipped),
        ]);
        if fusion {
            assert!(
                l.fused_steps > 0,
                "no fused lane groups formed under load: {l:?}"
            );
            assert!(
                l.steps_per_dispatch() > 1.0,
                "fusion on but <= 1 decode step per dispatch: {l:?}"
            );
        } else {
            assert_eq!(l.fused_calls, 0, "lanes off but fused calls ran");
        }
        lane_tput.push(m.throughput_tps());
        lane_outputs.push(
            out.responses.iter().map(|r| r.output.tokens.clone()).collect(),
        );
    }
    lane_table.emit("serving_throughput");
    assert_eq!(
        lane_outputs[0], lane_outputs[1],
        "lane fusion changed generated tokens"
    );
    println!(
        "lane fusion throughput ratio (on/off): {:.2}x",
        lane_tput[1] / lane_tput[0].max(1e-9)
    );

    // --- Device-resident lane groups vs per-step round-trips ---
    // Shape checks: tokens are byte-identical with residency on vs off,
    // warm group hits actually happen under residency, and resident
    // steady-state decode moves **zero** per-step cache traffic — every
    // gather is attributable to group formation (cold forms), while the
    // round-trip run pays lane x stage gathers and scatters on every
    // fused step.
    let mut res_table = Table::new(
        "Device-resident lane groups vs round-trip (shared-prefix \
         workload, max_concurrent 4)",
        &["resident", "tok/s", "warm hits", "cold forms", "gathers",
          "scatters", "gather KiB", "scatter KiB"],
    );
    let mut res_outputs: Vec<Vec<Vec<i32>>> = Vec::new();
    let mut res_tput = Vec::new();
    let mut res_gathers = Vec::new();
    for &residency in &[false, true] {
        let mut pool = EnginePool::new(
            state.clone(),
            PoolConfig {
                workers: 1,
                engine: EngineKind::Sequential,
                policy: ExitPolicy::confidence(0.6),
                sched: Policy::Fifo,
                max_concurrent: 4,
                prefix_cache_positions: 0,
                device_tier_positions: 0,
                convo_idle_ttl: std::time::Duration::from_secs(300),
                lane_fusion: true,
                lane_residency: residency,
                control: ControlConfig::default(),
            },
        );
        let out = pool.run_batch(shared_reqs.clone()).expect("batch");
        pool.shutdown().expect("shutdown");
        assert!(out.failures.is_empty(), "{:?}", out.failures);
        let m = &out.metrics;
        let l = &m.lanes;
        res_table.row(vec![
            if residency { "on".into() } else { "off".to_string() },
            format!("{:.1}", m.throughput_tps()),
            format!("{}", l.warm_group_hits),
            format!("{}", l.cold_group_forms),
            format!("{}", l.cache_gathers),
            format!("{}", l.cache_scatters),
            format!("{}", l.cache_gather_bytes / 1024),
            format!("{}", l.cache_scatter_bytes / 1024),
        ]);
        assert!(l.fused_steps > 0, "no fused lane groups formed: {l:?}");
        if residency {
            assert!(
                l.warm_group_hits > 0,
                "residency on but no warm group hits: {l:?}"
            );
            // Zero per-step traffic at steady state: every gather must
            // be part of a group formation, so total gathers are
            // bounded by cold forms x widest lane group x stages.
            let stages = state.man.stages.len() as u64;
            let max_lane =
                *state.man.decode_lanes.iter().max().unwrap_or(&0) as u64;
            assert!(
                l.cache_gathers <= l.cold_group_forms * max_lane * stages,
                "resident decode gathered outside group formation: {l:?}"
            );
        } else {
            assert_eq!(
                l.warm_group_hits, 0,
                "round-trip mode scored warm hits: {l:?}"
            );
            assert_eq!(
                l.cold_group_forms, 0,
                "round-trip mode formed resident groups: {l:?}"
            );
            // Round-trip decode pays at least one lane-cache gather per
            // fused step (one per stage actually run).
            assert!(
                l.cache_gathers >= l.fused_steps,
                "round-trip decode under-reported gathers: {l:?}"
            );
        }
        res_tput.push(m.throughput_tps());
        res_gathers.push(l.cache_gathers);
        res_outputs.push(
            out.responses.iter().map(|r| r.output.tokens.clone()).collect(),
        );
    }
    res_table.emit("serving_throughput");
    assert_eq!(
        res_outputs[0], res_outputs[1],
        "lane residency changed generated tokens"
    );
    assert!(
        res_gathers[1] < res_gathers[0],
        "residency did not reduce cache gathers: resident {} vs \
         round-trip {}",
        res_gathers[1],
        res_gathers[0]
    );
    println!(
        "lane residency throughput ratio (resident/round-trip): {:.2}x",
        res_tput[1] / res_tput[0].max(1e-9)
    );

    // --- Sequential vs pipelined engines on one serving workload ---
    // Shape checks: generated tokens are identical across engines,
    // pipelined pool workers actually interleave sessions on the stage
    // chain (in-flight occupancy >= 2 at max_concurrent 4), and the
    // throughput ratio is reported.
    let mut engine_table = Table::new(
        "Engine comparison (shared-prefix workload, max_concurrent 4)",
        &["engine", "tok/s", "rounds", "mean in flight", "max in flight"],
    );
    let mut engine_outputs: Vec<Vec<Vec<i32>>> = Vec::new();
    let mut engine_tput = Vec::new();
    for &kind in &[EngineKind::Sequential, EngineKind::Pipelined] {
        let mut pool = EnginePool::new(
            state.clone(),
            PoolConfig {
                workers: 1,
                engine: kind,
                policy: ExitPolicy::confidence(0.6),
                sched: Policy::Fifo,
                max_concurrent: 4,
                prefix_cache_positions: 0,
                device_tier_positions: 0,
                convo_idle_ttl: std::time::Duration::from_secs(300),
                lane_fusion: true,
                lane_residency: true,
                control: ControlConfig::default(),
            },
        );
        let out = pool.run_batch(shared_reqs.clone()).expect("batch");
        pool.shutdown().expect("shutdown");
        assert!(out.failures.is_empty(), "{:?}", out.failures);
        let m = &out.metrics;
        let il = &m.interleave;
        engine_table.row(vec![
            format!("{kind:?}"),
            format!("{:.1}", m.throughput_tps()),
            format!("{}", il.rounds),
            format!("{:.2}", il.mean_in_flight()),
            format!("{}", il.max_in_flight()),
        ]);
        if kind == EngineKind::Pipelined {
            assert!(
                il.occupancy.iter().any(|&(n, _)| n >= 2),
                "pipelined pool never overlapped sessions: {il:?}"
            );
        } else {
            assert_eq!(
                il.rounds, 0,
                "sequential pool ran interleaved rounds"
            );
        }
        engine_tput.push(m.throughput_tps());
        engine_outputs.push(
            out.responses.iter().map(|r| r.output.tokens.clone()).collect(),
        );
    }
    engine_table.emit("serving_throughput");
    assert_eq!(
        engine_outputs[0], engine_outputs[1],
        "engines generated different tokens"
    );
    println!(
        "pipelined/sequential serving throughput ratio: {:.2}x",
        engine_tput[1] / engine_tput[0].max(1e-9)
    );

    // --- SLO control plane: preemption + shedding on vs off ---
    // Bursty, diurnal, multi-tenant deadline traffic through a single
    // worker with two live slots: without the control plane, long
    // best-effort sessions hold the slots while deadlined requests
    // queue past their budgets; with it, urgent requests preempt the
    // lowest-value live session (parked, resumed later) and the queue
    // sheds load it cannot serve in time. Shape checks: the control
    // plane actually engages (sheds fire; every preempted session
    // resumes), and its deadline-miss rate is no worse than the
    // baseline's.
    let mut traffic_spec = TrafficSpec {
        seed: 29,
        n_requests: if bench_util::fast() { 10 } else { 18 },
        tenants: vec![3.0, 1.0],
        period: 8,
        burst_len: 3,
        deadline_ms: (1, 2),
        deadline_rate: 0.55,
        max_new: (4, 12),
        prompt_bytes: (32, (max_seq / 2).max(48)),
    };
    // Calibrate deadline bounds to the observed service time: run the
    // same traffic deadline-free, then set deadlines spanning "tight
    // enough to miss under queueing" to "comfortably loose".
    let base_cfg = PoolConfig {
        workers: 1,
        engine: EngineKind::Sequential,
        policy: ExitPolicy::confidence(0.6),
        sched: Policy::Priority,
        max_concurrent: 2,
        prefix_cache_positions: 0,
        device_tier_positions: 0,
        convo_idle_ttl: std::time::Duration::from_secs(300),
        lane_fusion: false,
        lane_residency: true,
        control: ControlConfig::default(),
    };
    let to_reqs = |traffic: &[eellm::data::synth::TrafficRequest],
                   deadlines: bool| {
        traffic
            .iter()
            .enumerate()
            .map(|(i, t)| {
                let mut r =
                    ServeRequest::new(i as u64, t.prompt.as_str(), t.max_new)
                        .with_priority(t.priority)
                        .with_tenant(t.tenant);
                if deadlines {
                    if let Some(ms) = t.deadline_ms {
                        r = r.with_deadline(
                            std::time::Duration::from_millis(ms),
                        );
                    }
                }
                r
            })
            .collect::<Vec<_>>()
    };
    let cal_traffic = bursty_traffic(&traffic_spec, &corpus.facts);
    let mut cal_pool = EnginePool::new(state.clone(), base_cfg.clone());
    let cal = cal_pool
        .run_batch(to_reqs(&cal_traffic, false))
        .expect("calibration batch");
    cal_pool.shutdown().expect("shutdown");
    let p50_ms = (cal.metrics.p50_latency_seconds * 1e3).max(1.0);
    traffic_spec.deadline_ms =
        ((p50_ms / 2.0).max(1.0) as u64, (p50_ms * 4.0).max(8.0) as u64);
    let traffic = bursty_traffic(&traffic_spec, &corpus.facts);
    let slo_reqs = to_reqs(&traffic, true);

    let mut slo_table = Table::new(
        "SLO control plane on bursty deadline traffic (1 worker, \
         max_concurrent 2, priority sched)",
        &["control", "tok/s", "deadlined", "misses", "miss rate",
          "preempt", "resume", "shed", "parked peak"],
    );
    let mut miss_rates = Vec::new();
    for &on in &[false, true] {
        let mut cfg = base_cfg.clone();
        if on {
            cfg.control = ControlConfig {
                preempt: true,
                preempt_horizon: std::time::Duration::from_millis(
                    (p50_ms * 4.0) as u64 + 8,
                ),
                park_capacity: 2,
                shed: Some(ShedPolicy {
                    max_queue_depth: traffic_spec.n_requests / 2,
                    max_predicted_ttft: None,
                    ..ShedPolicy::default()
                }),
                tenant_weights: traffic_spec.tenants.clone(),
                fault: None,
                ..ControlConfig::default()
            };
        }
        let mut pool = EnginePool::new(state.clone(), cfg);
        let out = pool.run_batch(slo_reqs.clone()).expect("batch");
        pool.shutdown().expect("shutdown");
        assert!(out.failures.is_empty(), "{:?}", out.failures);
        let m = &out.metrics;
        let s = &m.slo;
        slo_table.row(vec![
            if on { "on".into() } else { "off".to_string() },
            format!("{:.1}", m.throughput_tps()),
            format!("{}", m.deadlined),
            format!("{}", m.deadline_misses),
            format!("{:.0}%", 100.0 * m.deadline_miss_rate()),
            format!("{}", s.preemptions),
            format!("{}", s.resumes),
            format!("{}", s.shed),
            format!("{}", s.parked_peak),
        ]);
        if on {
            assert!(
                s.preemptions + s.shed > 0,
                "control plane on but never engaged: {s:?}"
            );
            assert_eq!(
                s.resumes, s.preemptions,
                "a preempted session never resumed: {s:?}"
            );
            assert_eq!(s.park_failures + s.resume_failures, 0, "{s:?}");
            for t in &m.tenants {
                println!(
                    "tenant {} share: {} requests, {} tokens ({:.0}%)",
                    t.tenant,
                    t.requests,
                    t.tokens,
                    100.0 * t.share
                );
            }
        } else {
            assert_eq!(s.preemptions + s.shed, 0, "{s:?}");
            assert!(
                out.sheds.is_empty(),
                "control plane off but requests were shed"
            );
        }
        miss_rates.push(m.deadline_miss_rate());
    }
    slo_table.emit("serving_throughput");
    if miss_rates[0] > 0.0 {
        assert!(
            miss_rates[1] <= miss_rates[0] + 1e-9,
            "control plane worsened the deadline-miss rate: on \
             {:.2} vs off {:.2}",
            miss_rates[1],
            miss_rates[0]
        );
    } else {
        println!(
            "baseline missed no deadlines at this speed; skipping the \
             miss-rate comparison"
        );
    }
    println!(
        "SLO miss rate off {:.0}% -> on {:.0}%",
        100.0 * miss_rates[0],
        100.0 * miss_rates[1]
    );
    // --- Tiered snapshot store: pinned device tier on vs off ---
    // Three passes of the shared-prefix workload through one pool: the
    // first seeds the host tier, repeat passes re-read every prefix, so
    // hot entries cross the promotion threshold and later lookups land
    // on the device tier. Shape checks: with a device budget the store
    // promotes hot prefixes and serves device hits, with none it never
    // does, and tier placement changes no generated token.
    let mut tier_table = Table::new(
        "Tiered snapshot store (shared-prefix workload, three passes)",
        &["device tier", "device hits", "host hits", "promote", "demote",
          "device hit rate"],
    );
    let mut tier_outputs: Vec<Vec<Vec<i32>>> = Vec::new();
    for &device in &[0usize, 4 * max_seq] {
        let mut pool = EnginePool::new(
            state.clone(),
            PoolConfig {
                workers: 1,
                engine: EngineKind::Sequential,
                policy: ExitPolicy::confidence(0.6),
                sched: Policy::Fifo,
                max_concurrent: 4,
                prefix_cache_positions: 8 * max_seq,
                device_tier_positions: device,
                convo_idle_ttl: std::time::Duration::from_secs(300),
                lane_fusion: false,
                lane_residency: true,
                control: ControlConfig::default(),
            },
        );
        let mut tier = TierStats::default();
        let mut toks: Vec<Vec<i32>> = Vec::new();
        for _pass in 0..3 {
            let out = pool.run_batch(shared_reqs.clone()).expect("batch");
            assert!(out.failures.is_empty(), "{:?}", out.failures);
            tier.merge(&out.metrics.tier);
            let mut pass: Vec<(u64, Vec<i32>)> = out
                .responses
                .iter()
                .map(|x| (x.id, x.output.tokens.clone()))
                .collect();
            pass.sort_by_key(|(id, _)| *id);
            toks.extend(pass.into_iter().map(|(_, t)| t));
        }
        pool.shutdown().expect("shutdown");
        tier_table.row(vec![
            if device == 0 { "off".into() } else { format!("{device} pos") },
            format!("{}", tier.device_hits),
            format!("{}", tier.host_hits),
            format!("{}", tier.promotions),
            format!("{}", tier.demotions),
            format!("{:.0}%", 100.0 * tier.device_hit_rate()),
        ]);
        assert!(
            tier.device_hits + tier.host_hits > 0,
            "shared prefixes scored no snapshot hits: {tier:?}"
        );
        if device == 0 {
            assert_eq!(
                tier.device_hits, 0,
                "device tier off but served a hit: {tier:?}"
            );
            assert_eq!(
                tier.promotions, 0,
                "device tier off but promoted: {tier:?}"
            );
        } else {
            assert!(
                tier.promotions > 0,
                "hot prefixes never promoted: {tier:?}"
            );
            assert!(
                tier.device_hits > 0,
                "promoted prefixes never served a device hit: {tier:?}"
            );
        }
        tier_outputs.push(toks);
    }
    tier_table.emit("serving_throughput");
    assert_eq!(
        tier_outputs[0], tier_outputs[1],
        "device tier changed generated tokens"
    );

    // --- Conversational serving: end-of-turn snapshots across turns ---
    // A multi-turn chat workload through a snapshot-enabled pool: every
    // completed turn stores its prompt-plus-generated KV state, and the
    // conversation's next turn restores it, prefilling only its own new
    // text. Shape checks, per engine at threshold 1.0 (deficit-free, so
    // the accounting is exact): round 0 registers every conversation as
    // a first turn; every later round restores a snapshot for every
    // conversation (no misses) and the positions actually prefilled are
    // bounded by the round's new user text plus a few tokens of slack
    // per turn — turn-N prefill is O(new turn), not O(history). A cold
    // replay of the byte-identical prompts through a snapshot-free pool
    // must generate identical token streams.
    let convo_spec = ConvoSpec {
        seed: 17,
        n_conversations: if bench_util::fast() { 3 } else { 5 },
        turns: 3,
        n_system: 2,
        system_bytes: 48,
        tenants: vec![1.0],
        max_new: (2, 4),
        think_ms: (0, 1),
    };
    let convos = conversation_traffic(&convo_spec, &corpus.facts);
    let n_convos = convos.len();
    let mut convo_table = Table::new(
        "Conversational serving: warm snapshots vs cold replay",
        &["engine", "mode", "turns", "restores", "prefill paid",
          "new-text bound", "snapshots"],
    );
    for &kind in &[EngineKind::Sequential, EngineKind::Pipelined] {
        let warm_cfg = PoolConfig {
            workers: 1,
            engine: kind,
            policy: ExitPolicy::confidence(1.0),
            sched: Policy::Fifo,
            max_concurrent: 2,
            prefix_cache_positions: 16 * max_seq,
            device_tier_positions: 2 * max_seq,
            convo_idle_ttl: std::time::Duration::from_secs(300),
            lane_fusion: false,
            lane_residency: true,
            control: ControlConfig::default(),
        };
        let mut warm = EnginePool::new(state.clone(), warm_cfg.clone());
        let mut history: Vec<String> = vec![String::new(); n_convos];
        let mut plan: Vec<Vec<(u64, String, usize)>> = Vec::new();
        let mut warm_streams: Vec<Vec<Vec<i32>>> =
            vec![Vec::new(); n_convos];
        let mut agg = ConvoStats::default();
        let mut paid_total = 0u64;
        let mut bound_total = 0u64;
        for r in 0..convo_spec.turns {
            let mut round: Vec<(u64, String, usize)> = Vec::new();
            let mut reqs = Vec::new();
            let mut new_text = 0usize;
            for (c, track) in convos.iter().enumerate() {
                let t = &track[r];
                let prompt = format!("{}{}", history[c], t.user_text);
                assert!(
                    prompt.len() + t.max_new + 4 < max_seq,
                    "conversation outgrew max_seq; shrink ConvoSpec"
                );
                new_text += t.user_text.len();
                let id = (r * n_convos + c) as u64;
                reqs.push(
                    ServeRequest::new(id, prompt.as_str(), t.max_new)
                        .with_conversation(c as u64),
                );
                round.push((id, prompt, t.max_new));
            }
            let out = warm.run_batch(reqs).expect("warm convo batch");
            assert!(out.failures.is_empty(), "{:?}", out.failures);
            let cv = &out.metrics.convo;
            assert_eq!(cv.snapshot_failures, 0, "{kind:?}: {cv:?}");
            assert_eq!(cv.snapshots_rejected, 0, "{kind:?}: {cv:?}");
            assert_eq!(
                cv.snapshots as usize, n_convos,
                "{kind:?} round {r}: a turn finished unsnapshotted: {cv:?}"
            );
            let total_prompt: u64 =
                round.iter().map(|(_, p, _)| p.len() as u64).sum();
            if r == 0 {
                assert_eq!(
                    cv.first_turns as usize, n_convos,
                    "{kind:?}: opening turns miscounted: {cv:?}"
                );
            } else {
                assert_eq!(
                    cv.restore_hits as usize, n_convos,
                    "{kind:?} round {r}: follow-up turns missed their \
                     snapshots: {cv:?}"
                );
                assert_eq!(cv.restore_misses, 0, "{kind:?}: {cv:?}");
                // O(new turn): positions prefilled this round = prompt
                // bytes minus restore savings.
                assert!(cv.saved_positions <= total_prompt);
                let paid = total_prompt - cv.saved_positions;
                let bound = (new_text + 4 * n_convos) as u64;
                assert!(
                    paid <= bound,
                    "{kind:?} round {r}: turn prefill is not O(new \
                     turn): paid {paid} positions > bound {bound}"
                );
                paid_total += paid;
                bound_total += bound;
            }
            agg.merge(cv);
            for (id, prompt, _) in &round {
                let rsp = out
                    .responses
                    .iter()
                    .find(|x| x.id == *id)
                    .expect("warm response");
                let c = (*id as usize) % n_convos;
                history[c] = format!("{prompt}{}", rsp.output.text);
                warm_streams[c].push(rsp.output.tokens.clone());
            }
            plan.push(round);
        }
        warm.shutdown().expect("shutdown");
        let follow = (convo_spec.turns - 1) * n_convos;
        convo_table.row(vec![
            format!("{kind:?}"),
            "warm".into(),
            format!("{}", agg.turns),
            format!("{}/{follow}", agg.restore_hits),
            format!("{paid_total} pos"),
            format!("{bound_total} pos"),
            format!("{}", agg.snapshots),
        ]);

        let mut cold = EnginePool::new(
            state.clone(),
            PoolConfig {
                prefix_cache_positions: 0,
                device_tier_positions: 0,
                ..warm_cfg
            },
        );
        let mut cold_streams: Vec<Vec<Vec<i32>>> =
            vec![Vec::new(); n_convos];
        for round in &plan {
            let reqs: Vec<ServeRequest> = round
                .iter()
                .map(|(id, p, m)| ServeRequest::new(*id, p.as_str(), *m))
                .collect();
            let out = cold.run_batch(reqs).expect("cold convo batch");
            assert!(out.failures.is_empty(), "{:?}", out.failures);
            assert_eq!(
                out.metrics.convo.turns, 0,
                "untagged replay recorded conversation turns"
            );
            for (id, _, _) in round {
                let rsp = out
                    .responses
                    .iter()
                    .find(|x| x.id == *id)
                    .expect("cold response");
                cold_streams[(*id as usize) % n_convos]
                    .push(rsp.output.tokens.clone());
            }
        }
        cold.shutdown().expect("shutdown");
        convo_table.row(vec![
            format!("{kind:?}"),
            "cold".into(),
            "0".into(),
            "-".into(),
            "-".into(),
            "-".into(),
            "0".into(),
        ]);
        assert_eq!(
            warm_streams, cold_streams,
            "{kind:?}: conversation snapshots changed generated tokens"
        );
    }
    convo_table.emit("serving_throughput");
    println!(
        "conversation snapshots: every follow-up turn restored; \
         turn prefill bounded by new text on both engines"
    );

    println!("serving_throughput shape checks OK");
}
