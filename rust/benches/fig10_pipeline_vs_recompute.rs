//! Figure 10 / Appendix B.1 reproduction: inference latency of the
//! pipeline-based method vs KV recomputation, across confidence
//! thresholds, on summarisation-style prompts (the paper's XSUM/CNN-DM
//! setting).
//!
//! Expected shape: both methods produce identical outputs; each
//! accelerates as the threshold decreases. (Relative standing depends on
//! the substrate: on the paper's A100s recomputation's batching is nearly
//! free, while our thread-per-stage pipeline pays P2P hops in thread
//! wakeups — the crossover is reported, not assumed.)

#[path = "bench_util.rs"]
mod bench_util;

use eellm::data::tasks;
use eellm::inference::{ExitPolicy, PipelinedEngine, SequentialEngine};
use eellm::util::table::Table;

fn main() {
    let steps = if bench_util::fast() { 60 } else { 400 };
    let Some(state) = bench_util::trained_state("ee-tiny", steps) else {
        return;
    };
    let corpus = bench_util::corpus();
    let n = if bench_util::fast() { 3 } else { 8 };
    let mut task = tasks::summary(&corpus, n, 9);
    let max_new = 32;
    let cap = state.man.model.max_seq;
    task.examples.retain(|e| e.prompt.len() + max_new + 4 < cap);
    assert!(!task.examples.is_empty(), "no summary examples fit cap {cap}");

    let thresholds = [1.0f32, 0.8, 0.5, 0.3, 0.2];
    let mut table = Table::new(
        "Figure 10: latency, pipeline-based vs KV recomputation",
        &[
            "threshold",
            "recompute ms/seq",
            "pipelined ms/seq",
            "outputs equal",
        ],
    );

    let mut pipe = PipelinedEngine::new(state.clone(), ExitPolicy::confidence(1.0)).expect("pipe");
    let mut rec_best = f64::INFINITY;
    let mut rec_base = 0.0f64;
    for &tau in &thresholds {
        let mut seq =
            SequentialEngine::new(state.clone(), ExitPolicy::confidence(tau))
                .expect("seq");
        pipe.set_policy(ExitPolicy::confidence(tau));
        let mut t_rec = 0.0;
        let mut t_pipe = 0.0;
        let mut equal = true;
        let mut forced = 0usize;
        for ex in &task.examples {
            let a = seq.generate_text(&ex.prompt, max_new).expect("rec");
            let b = pipe.generate_text(&ex.prompt, max_new).expect("pipe");
            t_rec += a.seconds;
            t_pipe += b.seconds;
            equal &= a.tokens == b.tokens;
            forced += a.stats.forced_full;
        }
        let n = task.examples.len() as f64;
        if tau >= 1.0 {
            rec_base = t_rec / n;
        }
        rec_best = rec_best.min(t_rec / n);
        table.row(vec![
            format!("{tau}"),
            format!("{:.1}", t_rec / n * 1e3),
            format!("{:.1}", t_pipe / n * 1e3),
            format!("{equal} (forced {forced})"),
        ]);
        // The App. B.1 equality claim holds whenever the recompute
        // engine's deficit cap never binds: a forced full-model pass
        // suppresses an exit the pipelined engine (which needs no cap)
        // would take. Assert equality only in the cap-free regime.
        assert!(
            equal || forced > 0,
            "engines diverged at tau={tau} without any forced full pass"
        );
    }
    table.emit("fig10");

    // Shape: early exiting accelerates the recompute engine.
    assert!(
        rec_best < rec_base,
        "no acceleration: best {rec_best} vs base {rec_base}"
    );
    println!("fig10 shape checks OK");
    pipe.shutdown();
}
