#!/usr/bin/env bash
# Lint gate for the Rust tier, invoked alongside tier-1
# (`cargo build --release && cargo test -q`):
#
#     bash rust/lint.sh
#
# Formatting must be clean and clippy warnings are errors.
set -euo pipefail
cd "$(dirname "$0")"
cargo fmt --check
cargo clippy --all-targets -- -D warnings
echo "lint gate OK"
