//! Integration: the SLO control plane must be **lossless and typed**.
//!
//! Deadline-driven preemption parks a live [`DecodeSession`] as a host
//! snapshot and resumes it later; that park/resume cycle must be
//! output-invisible — token-for-token and exit-layer-for-exit-layer
//! identical to an uninterrupted run — on both engines, across exit
//! policies (including the `Confidence{1.0}` and `Never` full-model
//! baselines), and on sessions restored from a prefix-cache hit. Park
//! and resume faults must surface as typed per-request failures without
//! deadlocking the pool or wiping the batch, admission control must
//! surface sheds as first-class [`Outcome`]s, and under `Priority` +
//! preemption the deadline-miss rate at fixed offered load must be
//! strictly lower than the no-preemption baseline.

use std::collections::BTreeMap;
use std::path::PathBuf;
use std::time::{Duration, Instant};

use eellm::config::{LossWeightSchedule, LrSchedule};
use eellm::data::dataset::{Dataset, TrainBatch};
use eellm::data::synth::{bursty_traffic, Corpus, CorpusSpec, TrafficSpec};
use eellm::inference::{
    DecodeBackend, DecodeSession, ExitPolicy, ModelState, PipelinedEngine,
    PrefixCacheStore, SequentialEngine, StepEvent,
};
use eellm::runtime::artifacts::Manifest;
use eellm::serve::{
    BatchOutcome, ControlConfig, ControlFault, EngineKind, EnginePool,
    Outcome, Policy, PoolConfig, ServeEvent, ServeRequest, ShedPolicy,
};
use eellm::training::trainer::{PipelineTrainer, TrainerOptions};

fn artifacts_root() -> PathBuf {
    PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts")
}

fn have_artifacts() -> bool {
    let ok = artifacts_root().join("ee-tiny").join("manifest.json").is_file();
    if !ok {
        eprintln!("skipping: run `make artifacts`");
    }
    ok
}

/// Train ee-tiny briefly so confidences are meaningful (same recipe as
/// the sibling equivalence suites).
fn trained_state(man: &Manifest, steps: usize) -> ModelState {
    let corpus = Corpus::build(&CorpusSpec {
        seed: 7,
        n_entities: 8,
        target_bytes: 120_000,
    });
    let mut ds =
        Dataset::from_corpus(&corpus, man.model.seq, man.model.microbatch, 3);
    let mut trainer = PipelineTrainer::new(
        man.clone(),
        TrainerOptions {
            seed: 42,
            lr: LrSchedule::cosine(3e-3, 5, steps),
            grad_clip: 1.0,
            loss_weights: LossWeightSchedule::Constant,
            total_steps: steps,
            bubble_fill: 0,
            bf_ratio: 2.0,
        },
    )
    .unwrap();
    for _ in 0..steps {
        let batches: Vec<TrainBatch> =
            (0..2).map(|_| ds.next_microbatch()).collect();
        trainer.train_step(&batches, &[]).unwrap();
    }
    let params = trainer.params().unwrap();
    trainer.shutdown();
    ModelState { man: man.clone(), stage_params: params }
}

/// Drain one serial session, collecting its (token, exit layer) stream.
fn serial_stream(
    backend: &mut dyn DecodeBackend,
    prompt: &str,
    max_new: usize,
) -> Vec<(i32, usize)> {
    let mut s = DecodeSession::new_text(backend, prompt, max_new).unwrap();
    s.prefill(backend).unwrap();
    let mut out = Vec::new();
    while !s.is_done() {
        if let StepEvent::Token { token, exit_layer, .. } =
            s.step(backend).unwrap()
        {
            out.push((token, exit_layer));
        }
    }
    s.close(backend);
    out
}

/// Decode `prompt`, parking the session after `park_after` tokens and
/// resuming it after a whole *other* session used the freed engine —
/// returning the stitched stream, or `None` if the stream finished
/// before the park point (nothing to prove there).
fn park_resume_stream(
    backend: &mut dyn DecodeBackend,
    prompt: &str,
    max_new: usize,
    park_after: usize,
    side_prompt: &str,
) -> Option<Vec<(i32, usize)>> {
    let mut s = DecodeSession::new_text(backend, prompt, max_new).unwrap();
    s.prefill(backend).unwrap();
    let mut out = Vec::new();
    while out.len() < park_after && !s.is_done() {
        if let StepEvent::Token { token, exit_layer, .. } =
            s.step(backend).unwrap()
        {
            out.push((token, exit_layer));
        }
    }
    if s.is_done() {
        s.close(backend);
        return None;
    }
    let parked = s.park(backend).unwrap();
    // The freed slot is genuinely free: run a full unrelated session
    // while the snapshot sits parked.
    assert!(
        !serial_stream(backend, side_prompt, 4).is_empty(),
        "side session on the freed engine emitted nothing"
    );
    let mut s = parked.resume(backend).unwrap();
    while !s.is_done() {
        if let StepEvent::Token { token, exit_layer, .. } =
            s.step(backend).unwrap()
        {
            out.push((token, exit_layer));
        }
    }
    s.close(backend);
    Some(out)
}

const PROMPTS: [&str; 6] = [
    "the capital of ",
    "question: what is the ",
    "count: 3 4 5 ",
    "abc: a b c d ",
    "the color of ",
    "fact: the capital ",
];

/// The headline bar: a session parked mid-decode and later resumed
/// emits a stream identical to an uninterrupted run, on both engines,
/// across >= 3 exit policies including the `Confidence{1.0}` and
/// `Never` full-model baselines.
#[test]
fn parked_session_resumes_identical_stream_on_both_engines() {
    if !have_artifacts() {
        return;
    }
    let man = Manifest::load_config(&artifacts_root(), "ee-tiny").unwrap();
    let state = trained_state(&man, 60);
    let policies = [
        ExitPolicy::confidence(0.4),
        ExitPolicy::confidence(1.0),
        ExitPolicy::Never,
        ExitPolicy::Entropy { max_nats: 1.0 },
    ];
    fn check(backend: &mut dyn DecodeBackend, label: &str) {
        let mut parked = 0;
        for (i, p) in PROMPTS.iter().enumerate() {
            let want = serial_stream(backend, p, 10);
            assert!(!want.is_empty(), "{label}: empty stream for {p:?}");
            let side = PROMPTS[(i + 1) % PROMPTS.len()];
            if let Some(got) =
                park_resume_stream(backend, p, 10, 2, side)
            {
                parked += 1;
                assert_eq!(
                    got, want,
                    "{label}, prompt {p:?}: parked-and-resumed stream \
                     diverged from the uninterrupted run"
                );
            }
        }
        assert!(parked > 0, "{label}: no prompt survived to the park point");
    }
    for policy in &policies {
        let mut seq =
            SequentialEngine::new(state.clone(), policy.clone()).unwrap();
        check(&mut seq, &format!("sequential/{policy}"));
        let mut pipe =
            PipelinedEngine::new(state.clone(), policy.clone()).unwrap();
        check(&mut pipe, &format!("pipelined/{policy}"));
        pipe.shutdown();
    }
}

/// Park/resume composes with the prefix KV cache: a session restored
/// from a cached prefix, parked mid-decode, and resumed still matches
/// the uninterrupted cache-off stream, on both engines.
#[test]
fn parked_resume_with_prefix_cache_on() {
    if !have_artifacts() {
        return;
    }
    let man = Manifest::load_config(&artifacts_root(), "ee-tiny").unwrap();
    let state = trained_state(&man, 60);
    let policy = ExitPolicy::confidence(0.6);
    let prefix = "fact: the capital of freedonia is ";
    let prompt = format!("{prefix}a city called ");
    fn check(
        backend: &mut dyn DecodeBackend,
        label: &str,
        prefix: &str,
        prompt: &str,
        budget: usize,
    ) {
        let want = serial_stream(backend, prompt, 8);
        assert!(!want.is_empty(), "{label}: empty reference stream");
        let store = PrefixCacheStore::new(budget);
        let mut d = DecodeSession::new_text(backend, prefix, 8).unwrap();
        d.prefill(backend).unwrap();
        assert!(store.insert(d.prefix_snapshot(backend).unwrap()));
        d.close(backend);
        let mut s = DecodeSession::new_text(backend, prompt, 8).unwrap();
        let rep = s.prefill_with_cache(backend, &store).unwrap();
        assert!(
            rep.cached_tokens > 0 && rep.saved_positions > 0,
            "{label}: prefix restore missed: {rep:?}"
        );
        let mut got = Vec::new();
        while got.len() < 2 && !s.is_done() {
            if let StepEvent::Token { token, exit_layer, .. } =
                s.step(backend).unwrap()
            {
                got.push((token, exit_layer));
            }
        }
        assert!(!s.is_done(), "{label}: stream ended before the park");
        let parked = s.park(backend).unwrap();
        let mut s = parked.resume(backend).unwrap();
        while !s.is_done() {
            if let StepEvent::Token { token, exit_layer, .. } =
                s.step(backend).unwrap()
            {
                got.push((token, exit_layer));
            }
        }
        s.close(backend);
        assert_eq!(
            got, want,
            "{label}: cache-hit + park/resume diverged from the \
             uninterrupted cache-off stream"
        );
    }
    let budget = 8 * man.model.max_seq;
    let mut seq =
        SequentialEngine::new(state.clone(), policy.clone()).unwrap();
    check(&mut seq, "sequential", prefix, &prompt, budget);
    let mut pipe = PipelinedEngine::new(state.clone(), policy).unwrap();
    check(&mut pipe, "pipelined", prefix, &prompt, budget);
    pipe.shutdown();
}

const BLOCKER: &str = "abc: a b c d ";
const URGENT: &str = "the capital of ";

fn control_cfg(
    engine: EngineKind,
    sched: Policy,
    preempt: bool,
    fault: Option<ControlFault>,
) -> PoolConfig {
    PoolConfig {
        workers: 1,
        engine,
        policy: ExitPolicy::confidence(0.4),
        sched,
        max_concurrent: 1,
        prefix_cache_positions: 0,
        device_tier_positions: 0,
        convo_idle_ttl: Duration::from_secs(300),
        lane_fusion: true,
        lane_residency: true,
        control: ControlConfig {
            preempt,
            // Any queued deadline counts as urgent — the tests pin
            // urgency via the deadline, not the horizon.
            preempt_horizon: Duration::from_secs(60),
            park_capacity: 1,
            shed: None,
            tenant_weights: Vec::new(),
            fault,
            heal: eellm::serve::HealConfig::default(),
        },
    }
}

/// Time one solo decode on a fresh engine (after a warmup decode, so
/// the measurement is serving time, not first-call setup).
fn solo_seconds(state: &ModelState, prompt: &str, max_new: usize) -> f64 {
    let mut eng = SequentialEngine::new(
        state.clone(),
        ExitPolicy::confidence(0.4),
    )
    .unwrap();
    let _ = serial_stream(&mut eng, prompt, max_new);
    let t0 = Instant::now();
    let _ = serial_stream(&mut eng, prompt, max_new);
    t0.elapsed().as_secs_f64()
}

/// A blocker holding the only live slot, then an urgent deadlined
/// request arriving mid-decode: the pool must park the blocker, serve
/// the urgent request, and resume the blocker — with BOTH streams
/// identical to uninterrupted solo runs, on both engines.
#[test]
fn pool_preemption_is_output_invisible() {
    if !have_artifacts() {
        return;
    }
    let man = Manifest::load_config(&artifacts_root(), "ee-tiny").unwrap();
    let state = trained_state(&man, 60);
    let t_b = solo_seconds(&state, BLOCKER, 24);
    let offset = Duration::from_secs_f64((t_b / 8.0).max(0.002));
    for &engine in &[EngineKind::Sequential, EngineKind::Pipelined] {
        let policy = ExitPolicy::confidence(0.4);
        let (want_blocker, want_urgent) = match engine {
            EngineKind::Sequential => {
                let mut e =
                    SequentialEngine::new(state.clone(), policy.clone())
                        .unwrap();
                (serial_stream(&mut e, BLOCKER, 24),
                 serial_stream(&mut e, URGENT, 4))
            }
            EngineKind::Pipelined => {
                let mut e =
                    PipelinedEngine::new(state.clone(), policy.clone())
                        .unwrap();
                let w = (serial_stream(&mut e, BLOCKER, 24),
                         serial_stream(&mut e, URGENT, 4));
                e.shutdown();
                w
            }
        };
        let reqs = vec![
            ServeRequest::new(0, BLOCKER, 24),
            ServeRequest::new(1, URGENT, 4)
                .with_deadline(Duration::from_millis(1))
                .with_start_after(offset),
        ];
        let mut pool = EnginePool::new(
            state.clone(),
            control_cfg(engine, Policy::Fifo, true, None),
        );
        let mut streams: BTreeMap<u64, Vec<(i32, usize)>> = BTreeMap::new();
        let out = pool
            .run_batch_streamed(reqs, |ev| {
                if let ServeEvent::Token { id, token, exit_layer, .. } = ev
                {
                    streams
                        .entry(*id)
                        .or_default()
                        .push((*token, *exit_layer));
                }
            })
            .unwrap();
        pool.shutdown().unwrap();
        assert!(out.failures.is_empty(), "{engine:?}: {:?}", out.failures);
        assert!(out.sheds.is_empty());
        assert_eq!(out.responses.len(), 2, "{engine:?}");
        let s = &out.metrics.slo;
        assert_eq!(
            s.preemptions, 1,
            "{engine:?}: the urgent arrival did not preempt the \
             blocker: {s:?}"
        );
        assert_eq!(s.resumes, 1, "{engine:?}: {s:?}");
        assert_eq!(s.park_failures + s.resume_failures, 0, "{engine:?}");
        assert_eq!(s.parked_peak, 1, "{engine:?}: {s:?}");
        assert_eq!(
            streams[&0], want_blocker,
            "{engine:?}: preempted-and-resumed blocker stream diverged \
             from its uninterrupted solo run"
        );
        assert_eq!(
            streams[&1], want_urgent,
            "{engine:?}: urgent stream diverged from its solo run"
        );
    }
}

/// Run a pool batch on its own thread with a watchdog: fault-injection
/// bugs must surface as typed failures, never as a hung completion
/// loop.
fn run_with_watchdog(
    state: ModelState,
    cfg: PoolConfig,
    reqs: Vec<ServeRequest>,
) -> BatchOutcome {
    let (tx, rx) = std::sync::mpsc::channel();
    let h = std::thread::spawn(move || {
        let mut pool = EnginePool::new(state, cfg);
        let out = pool.run_batch(reqs).expect("batch");
        pool.shutdown().expect("shutdown");
        let _ = tx.send(out);
    });
    let out = rx
        .recv_timeout(Duration::from_secs(120))
        .expect("pool deadlocked under fault injection");
    h.join().unwrap();
    out
}

fn preemption_reqs(state: &ModelState) -> Vec<ServeRequest> {
    let t_b = solo_seconds(state, BLOCKER, 24);
    let offset = Duration::from_secs_f64((t_b / 8.0).max(0.002));
    vec![
        ServeRequest::new(0, BLOCKER, 24),
        ServeRequest::new(1, URGENT, 4)
            .with_deadline(Duration::from_millis(1))
            .with_start_after(offset),
    ]
}

/// An injected snapshot failure during park fails the *victim* request
/// with a typed error; the urgent request is still admitted and served,
/// and the pool neither deadlocks nor wipes the batch.
#[test]
fn park_fault_is_a_typed_failure_not_a_deadlock() {
    if !have_artifacts() {
        return;
    }
    let man = Manifest::load_config(&artifacts_root(), "ee-tiny").unwrap();
    let state = trained_state(&man, 60);
    let reqs = preemption_reqs(&state);
    let out = run_with_watchdog(
        state.clone(),
        control_cfg(
            EngineKind::Sequential,
            Policy::Fifo,
            true,
            Some(ControlFault::ParkSnapshot),
        ),
        reqs,
    );
    assert_eq!(out.failures.len(), 1, "{:?}", out.failures);
    let f = &out.failures[0];
    assert_eq!(f.id, 0, "the park fault must fail the victim");
    assert!(
        f.error.contains("park failed") && f.error.contains("injected"),
        "untyped park failure: {f:?}"
    );
    assert_eq!(out.responses.len(), 1);
    assert_eq!(out.responses[0].id, 1, "the urgent request must survive");
    let s = &out.metrics.slo;
    assert_eq!(s.park_failures, 1, "{s:?}");
    assert_eq!(s.preemptions, 0, "a failed park is not a preemption");
    assert_eq!(s.resumes, 0, "{s:?}");
    // Typed outcomes cover the whole batch, in id order.
    let outcomes = out.outcomes();
    assert_eq!(outcomes.len(), 2);
    assert!(matches!(outcomes[0], Outcome::Failed(_)));
    assert!(matches!(outcomes[1], Outcome::Done(_)));
}

/// An injected restore failure during resume fails the parked request
/// with a typed error after the urgent request completed; no deadlock,
/// no batch wipe.
#[test]
fn resume_fault_is_a_typed_failure_not_a_deadlock() {
    if !have_artifacts() {
        return;
    }
    let man = Manifest::load_config(&artifacts_root(), "ee-tiny").unwrap();
    let state = trained_state(&man, 60);
    let reqs = preemption_reqs(&state);
    let out = run_with_watchdog(
        state.clone(),
        control_cfg(
            EngineKind::Sequential,
            Policy::Fifo,
            true,
            Some(ControlFault::ResumeRestore),
        ),
        reqs,
    );
    assert_eq!(out.failures.len(), 1, "{:?}", out.failures);
    let f = &out.failures[0];
    assert_eq!(f.id, 0, "the resume fault must fail the parked victim");
    assert!(
        f.error.contains("resume failed") && f.error.contains("injected"),
        "untyped resume failure: {f:?}"
    );
    assert_eq!(out.responses.len(), 1);
    assert_eq!(out.responses[0].id, 1);
    let s = &out.metrics.slo;
    assert_eq!(s.preemptions, 1, "the park itself must have succeeded");
    assert_eq!(s.resume_failures, 1, "{s:?}");
    assert_eq!(s.resumes, 0, "{s:?}");
}

/// The regression bar: under `Policy::Priority` at fixed offered load,
/// preemption strictly lowers the deadline-miss rate versus the
/// no-preemption baseline.
#[test]
fn contended_priority_preemption_strictly_lowers_miss_rate() {
    if !have_artifacts() {
        return;
    }
    let man = Manifest::load_config(&artifacts_root(), "ee-tiny").unwrap();
    let state = trained_state(&man, 60);
    let t_u = solo_seconds(&state, URGENT, 2);
    let t_b = solo_seconds(&state, BLOCKER, 24);
    if t_b < 6.0 * t_u {
        eprintln!(
            "skipping: blocker/urgent service ratio too small for a \
             crisp contrast ({t_b:.4}s vs {t_u:.4}s)"
        );
        return;
    }
    // The urgent request arrives while the blocker holds the only live
    // slot; its deadline is far beyond its own service time but well
    // inside the blocker's remaining runtime — so the baseline must
    // miss it and the preempting pool must not.
    let deadline = Duration::from_secs_f64(t_b / 2.0);
    let offset = Duration::from_secs_f64((t_b / 8.0).max(0.002));
    let reqs = vec![
        ServeRequest::new(0, BLOCKER, 24),
        ServeRequest::new(1, URGENT, 2)
            .with_deadline(deadline)
            .with_start_after(offset),
    ];
    let mut rates = Vec::new();
    for &preempt in &[false, true] {
        let mut pool = EnginePool::new(
            state.clone(),
            control_cfg(
                EngineKind::Sequential,
                Policy::Priority,
                preempt,
                None,
            ),
        );
        let out = pool.run_batch(reqs.clone()).unwrap();
        pool.shutdown().unwrap();
        assert!(out.failures.is_empty(), "{:?}", out.failures);
        assert_eq!(out.responses.len(), 2);
        let m = &out.metrics;
        assert_eq!(m.deadlined, 1);
        if preempt {
            assert!(
                out.metrics.slo.preemptions >= 1,
                "preemption enabled but never fired: {:?}",
                out.metrics.slo
            );
        } else {
            assert_eq!(out.metrics.slo.preemptions, 0);
        }
        rates.push(m.deadline_miss_rate());
    }
    assert!(
        rates[0] > 0.0,
        "baseline served the urgent request inside a deadline half the \
         blocker's runtime — the load was not contended"
    );
    assert!(
        rates[1] < rates[0],
        "preemption did not strictly lower the deadline-miss rate: \
         on {} vs off {}",
        rates[1],
        rates[0]
    );
}

/// Bursty multi-tenant traffic through the full control plane: every
/// request resolves to exactly one typed outcome (done / shed), shed
/// events and counters agree, and per-tenant shares are reported with
/// the heavier-weighted tenant ahead.
#[test]
fn bursty_traffic_yields_typed_outcomes_and_tenant_shares() {
    if !have_artifacts() {
        return;
    }
    let man = Manifest::load_config(&artifacts_root(), "ee-tiny").unwrap();
    let state = trained_state(&man, 60);
    let corpus = Corpus::build(&CorpusSpec {
        seed: 7,
        n_entities: 8,
        target_bytes: 120_000,
    });
    let spec = TrafficSpec {
        seed: 13,
        n_requests: 12,
        tenants: vec![3.0, 1.0],
        period: 6,
        burst_len: 3,
        deadline_ms: (20, 200),
        deadline_rate: 0.6,
        max_new: (2, 6),
        prompt_bytes: (16, 64),
    };
    let traffic = bursty_traffic(&spec, &corpus.facts);
    assert!(traffic.iter().any(|t| t.tenant == 1), "single-tenant draw");
    let reqs: Vec<ServeRequest> = traffic
        .iter()
        .enumerate()
        .map(|(i, t)| {
            let mut r =
                ServeRequest::new(i as u64, t.prompt.as_str(), t.max_new)
                    .with_priority(t.priority)
                    .with_tenant(t.tenant);
            if let Some(ms) = t.deadline_ms {
                r = r.with_deadline(Duration::from_millis(ms));
            }
            r
        })
        .collect();
    let mut cfg = control_cfg(
        EngineKind::Sequential,
        Policy::Priority,
        true,
        None,
    );
    cfg.max_concurrent = 2;
    cfg.control.park_capacity = 2;
    cfg.control.tenant_weights = spec.tenants.clone();

    // Run A — shedding off: every request completes, so per-tenant
    // accounting covers the full offered load.
    let mut pool = EnginePool::new(state.clone(), cfg.clone());
    let out = pool.run_batch(reqs.clone()).unwrap();
    pool.shutdown().unwrap();
    assert!(out.failures.is_empty(), "{:?}", out.failures);
    assert!(out.sheds.is_empty());
    assert_eq!(out.responses.len(), 12);
    // Per-tenant accounting: both tenants reported, shares summing to
    // ~1, with the 3x-weighted tenant (which also offers ~3x the
    // traffic) ahead.
    let tenants = &out.metrics.tenants;
    assert_eq!(tenants.len(), 2, "{tenants:?}");
    let total: f64 = tenants.iter().map(|t| t.share).sum();
    assert!((total - 1.0).abs() < 1e-6, "{tenants:?}");
    assert!(
        tenants[0].share > tenants[1].share,
        "tenant shares do not track 3:1 weights: {tenants:?}"
    );
    assert!(out.metrics.p99_ttft_seconds >= out.metrics.p50_ttft_seconds);

    // Run B — a tight queue bound: the burst outruns one worker's
    // admission by construction, so load is shed as typed outcomes that
    // agree across events, counters, and the merged view.
    cfg.control.shed = Some(ShedPolicy {
        max_queue_depth: 2,
        max_predicted_ttft: None,
        ..ShedPolicy::default()
    });
    let mut pool = EnginePool::new(state.clone(), cfg);
    let mut shed_events = 0usize;
    let out = pool
        .run_batch_streamed(reqs, |ev| {
            if matches!(ev, ServeEvent::Shed { .. }) {
                shed_events += 1;
            }
        })
        .unwrap();
    pool.shutdown().unwrap();
    assert!(out.failures.is_empty(), "{:?}", out.failures);
    assert!(
        !out.sheds.is_empty(),
        "a 12-request burst against a depth-2 queue shed nothing"
    );
    assert_eq!(
        out.responses.len() + out.sheds.len(),
        12,
        "a request vanished without a typed outcome"
    );
    assert_eq!(shed_events, out.sheds.len());
    assert_eq!(out.metrics.slo.shed as usize, out.sheds.len());
    // outcomes() is the merged, id-ordered view of the whole batch.
    let outcomes = out.outcomes();
    assert_eq!(outcomes.len(), 12);
    for (i, o) in outcomes.iter().enumerate() {
        assert_eq!(o.id(), i as u64);
        assert!(!matches!(o, Outcome::Failed(_)));
    }
}
