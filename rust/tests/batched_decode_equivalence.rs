//! Integration: lane-fused batched decode must be **output-invisible**.
//!
//! Serving N concurrent sessions through the fused `decode_b{B}_w1`
//! executables (one batched XLA call per stage, per-lane exit decisions)
//! must produce token-for-token and exit-layer-for-exit-layer the same
//! streams as the solo windowed path — across exit policies (including
//! the `Confidence{1.0}` and `Never` full-model baselines), mixed
//! per-request policies, mid-flight admission, and with the prefix KV
//! cache on or off. The speedup claim is separate and observable:
//! fused lane groups must actually form under load (decode steps per
//! XLA dispatch > 1 at `max_concurrent` >= 4).

use std::collections::BTreeMap;
use std::path::PathBuf;

use eellm::config::{LossWeightSchedule, LrSchedule};
use eellm::data::dataset::{Dataset, TrainBatch};
use eellm::data::synth::{
    shared_prefix_prompts, Corpus, CorpusSpec, SharedPrefixSpec,
};
use eellm::inference::{
    DecodeBackend, DecodeSession, ExitPolicy, ModelState, SequentialEngine,
    StepEvent,
};
use eellm::runtime::artifacts::Manifest;
use eellm::serve::{
    BatchOutcome, ControlConfig, EngineKind, EnginePool, Policy,
    PoolConfig, ServeEvent, ServeRequest,
};
use eellm::training::trainer::{PipelineTrainer, TrainerOptions};

fn artifacts_root() -> PathBuf {
    PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts")
}

fn have_artifacts() -> bool {
    let ok = artifacts_root().join("ee-tiny").join("manifest.json").is_file();
    if !ok {
        eprintln!("skipping: run `make artifacts`");
    }
    ok
}

/// Train ee-tiny briefly so confidences are meaningful (same recipe as
/// the sibling equivalence suites).
fn trained_state(man: &Manifest, steps: usize) -> ModelState {
    let corpus = Corpus::build(&CorpusSpec {
        seed: 7,
        n_entities: 8,
        target_bytes: 120_000,
    });
    let mut ds =
        Dataset::from_corpus(&corpus, man.model.seq, man.model.microbatch, 3);
    let mut trainer = PipelineTrainer::new(
        man.clone(),
        TrainerOptions {
            seed: 42,
            lr: LrSchedule::cosine(3e-3, 5, steps),
            grad_clip: 1.0,
            loss_weights: LossWeightSchedule::Constant,
            total_steps: steps,
            bubble_fill: 0,
            bf_ratio: 2.0,
        },
    )
    .unwrap();
    for _ in 0..steps {
        let batches: Vec<TrainBatch> =
            (0..2).map(|_| ds.next_microbatch()).collect();
        trainer.train_step(&batches, &[]).unwrap();
    }
    let params = trainer.params().unwrap();
    trainer.shutdown();
    ModelState { man: man.clone(), stage_params: params }
}

type Streams = BTreeMap<u64, Vec<(i32, usize)>>;

/// Serve `reqs` on a one-worker pool and collect each request's
/// (token, exit layer) stream from the live event feed.
fn pooled_streams(
    state: &ModelState,
    policy: ExitPolicy,
    reqs: Vec<ServeRequest>,
    max_concurrent: usize,
    lane_fusion: bool,
    prefix_cache_positions: usize,
) -> (Streams, BatchOutcome) {
    let mut pool = EnginePool::new(
        state.clone(),
        PoolConfig {
            workers: 1,
            engine: EngineKind::Sequential,
            policy,
            sched: Policy::Fifo,
            max_concurrent,
            prefix_cache_positions,
            device_tier_positions: 0,
            convo_idle_ttl: std::time::Duration::from_secs(300),
            lane_fusion,
            lane_residency: true,
            control: ControlConfig::default(),
        },
    );
    let mut streams: Streams = BTreeMap::new();
    let out = pool
        .run_batch_streamed(reqs, |ev| {
            if let ServeEvent::Token { id, token, exit_layer, .. } = ev {
                streams.entry(*id).or_default().push((*token, *exit_layer));
            }
        })
        .unwrap();
    pool.shutdown().unwrap();
    assert!(out.failures.is_empty(), "{:?}", out.failures);
    (streams, out)
}

/// Drain one serial session, collecting its (token, exit layer) stream.
fn serial_stream(
    backend: &mut dyn DecodeBackend,
    prompt: &str,
    max_new: usize,
) -> Vec<(i32, usize)> {
    let mut s = DecodeSession::new_text(backend, prompt, max_new).unwrap();
    s.prefill(backend).unwrap();
    let mut out = Vec::new();
    while !s.is_done() {
        if let StepEvent::Token { token, exit_layer, .. } =
            s.step(backend).unwrap()
        {
            out.push((token, exit_layer));
        }
    }
    out
}

const PROMPTS: [&str; 6] = [
    "the capital of ",
    "question: what is the ",
    "count: 3 4 5 ",
    "abc: a b c d ",
    "the color of ",
    "fact: the capital ",
];

/// The acceptance grid: pooled streams with lanes enabled equal the
/// lanes-disabled pool and serial decoding, across >= 3 exit policies
/// including the `Confidence{1.0}` and `Never` full-model baselines.
#[test]
fn lanes_match_unfused_and_serial_across_policies() {
    if !have_artifacts() {
        return;
    }
    let man = Manifest::load_config(&artifacts_root(), "ee-tiny").unwrap();
    assert!(
        !man.decode_lanes.is_empty(),
        "ee-tiny manifest lists no decode_lanes; rebuild artifacts"
    );
    let state = trained_state(&man, 60);
    let policies = [
        ExitPolicy::confidence(0.2),
        ExitPolicy::confidence(0.6),
        ExitPolicy::confidence(1.0),
        ExitPolicy::Never,
        ExitPolicy::Entropy { max_nats: 1.0 },
    ];
    for policy in &policies {
        let reqs: Vec<ServeRequest> = PROMPTS
            .iter()
            .enumerate()
            .map(|(i, p)| ServeRequest::new(i as u64, *p, 12))
            .collect();
        let (on, m_on) = pooled_streams(
            &state,
            policy.clone(),
            reqs.clone(),
            4,
            true,
            0,
        );
        let (off, _) =
            pooled_streams(&state, policy.clone(), reqs, 4, false, 0);
        assert_eq!(
            on, off,
            "policy {policy}: lanes-on pool diverged from lanes-off"
        );
        let mut serial =
            SequentialEngine::new(state.clone(), policy.clone()).unwrap();
        for (i, p) in PROMPTS.iter().enumerate() {
            let want = serial_stream(&mut serial, p, 12);
            assert!(!want.is_empty(), "policy {policy}: empty stream");
            assert_eq!(
                on[&(i as u64)],
                want,
                "policy {policy}, prompt {p:?}: pooled lanes-on diverged \
                 from serial"
            );
        }
        // Same-policy live sessions must actually fuse (the un-fusable
        // exceptions are deficit-healing rounds after early exits).
        assert!(
            m_on.metrics.lanes.fused_steps > 0,
            "policy {policy}: no fused steps despite 4 live sessions"
        );
    }
}

/// Mixed per-request policies: lanes group same-policy sessions only,
/// and every stream still equals the lanes-off pool and the per-policy
/// serial engines.
#[test]
fn mixed_policy_batches_match_unfused_and_serial() {
    if !have_artifacts() {
        return;
    }
    let man = Manifest::load_config(&artifacts_root(), "ee-tiny").unwrap();
    let state = trained_state(&man, 60);
    let policies = [
        ExitPolicy::confidence(0.6),
        ExitPolicy::Never,
        ExitPolicy::confidence(0.6),
        ExitPolicy::confidence(0.2),
        ExitPolicy::Never,
        ExitPolicy::confidence(0.6),
    ];
    let reqs: Vec<ServeRequest> = PROMPTS
        .iter()
        .zip(&policies)
        .enumerate()
        .map(|(i, (p, pol))| {
            ServeRequest::new(i as u64, *p, 12).with_policy(pol.clone())
        })
        .collect();
    // Pool default differs from every request: a leak shows up as a
    // diverged stream.
    let default = ExitPolicy::confidence(0.9);
    let (on, m_on) =
        pooled_streams(&state, default.clone(), reqs.clone(), 6, true, 0);
    let (off, m_off) = pooled_streams(&state, default, reqs, 6, false, 0);
    assert_eq!(on, off, "mixed-policy lanes-on diverged from lanes-off");
    for (i, (p, pol)) in PROMPTS.iter().zip(&policies).enumerate() {
        let mut serial =
            SequentialEngine::new(state.clone(), pol.clone()).unwrap();
        assert_eq!(
            on[&(i as u64)],
            serial_stream(&mut serial, p, 12),
            "request {i} (policy {pol}) diverged from serial"
        );
    }
    // Policy-churn regression: policy-ordered rounds apply each distinct
    // policy once per round; the pre-lane loop swapped on every adjacent
    // policy change (~once per decode step on this interleaved set).
    for m in [&m_on, &m_off] {
        let l = &m.metrics.lanes;
        let steps = l.fused_steps + l.solo_steps;
        assert!(
            l.policy_applies < steps,
            "policy churn: {} applies for {steps} decode steps \
             (interleaved policies should batch per round): {l:?}",
            l.policy_applies
        );
    }
}

/// Mid-flight admission: more requests than live slots, so sessions
/// join while earlier ones are mid-generation and lane groups reshape
/// every round. Streams must match the lanes-off pool exactly.
#[test]
fn mid_flight_admission_matches_unfused() {
    if !have_artifacts() {
        return;
    }
    let man = Manifest::load_config(&artifacts_root(), "ee-tiny").unwrap();
    let state = trained_state(&man, 60);
    let reqs: Vec<ServeRequest> = (0..10)
        .map(|i| {
            let p = PROMPTS[i % PROMPTS.len()];
            // Varied budgets stagger completions, forcing admissions
            // into partially-drained rounds.
            ServeRequest::new(i as u64, p, 6 + (i % 5))
        })
        .collect();
    let policy = ExitPolicy::confidence(0.4);
    let (on, m_on) =
        pooled_streams(&state, policy.clone(), reqs.clone(), 3, true, 0);
    let (off, _) = pooled_streams(&state, policy, reqs, 3, false, 0);
    assert_eq!(on, off, "mid-flight admission diverged under lanes");
    assert!(m_on.metrics.lanes.fused_steps > 0, "no fusion under churn");
}

/// Prefix-cache interaction: restored-prefix sessions join lane groups
/// like any other, and all four (lanes x cache) combinations produce
/// identical streams. Also pins the bytes-accurate snapshot slicing:
/// a snapshot holds its live prefix, not the cache capacity.
#[test]
fn prefix_cache_and_lanes_compose() {
    if !have_artifacts() {
        return;
    }
    let man = Manifest::load_config(&artifacts_root(), "ee-tiny").unwrap();
    let state = trained_state(&man, 60);
    let max_seq = man.model.max_seq;
    let corpus = Corpus::build(&CorpusSpec {
        seed: 7,
        n_entities: 8,
        target_bytes: 120_000,
    });
    let spec = SharedPrefixSpec {
        seed: 11,
        n_groups: 2,
        requests_per_group: 4,
        prefix_bytes: max_seq / 2,
    };
    let prompts = shared_prefix_prompts(&spec, &corpus.facts);
    let reqs: Vec<ServeRequest> = prompts
        .iter()
        .enumerate()
        .map(|(i, p)| ServeRequest::new(i as u64, p.as_str(), 8))
        .collect();
    let policy = ExitPolicy::confidence(0.6);
    let mut all: Vec<Streams> = Vec::new();
    for &lanes in &[false, true] {
        for &budget in &[0usize, 8 * max_seq] {
            let (streams, out) = pooled_streams(
                &state,
                policy.clone(),
                reqs.clone(),
                4,
                lanes,
                budget,
            );
            if budget > 0 {
                assert!(
                    out.metrics.prefix.hits > 0,
                    "lanes {lanes}: no prefix hits on shared prompts"
                );
            }
            all.push(streams);
        }
    }
    for s in &all[1..] {
        assert_eq!(
            *s, all[0],
            "streams diverged across lanes x prefix-cache combinations"
        );
    }

    // Bytes-accurate snapshots: a short prompt's snapshot is sliced to
    // its live prefix along the position axis.
    let mut eng =
        SequentialEngine::new(state.clone(), ExitPolicy::confidence(0.6))
            .unwrap();
    let mut sess =
        DecodeSession::new_text(&mut eng, "the capital of ", 8).unwrap();
    sess.prefill(&mut eng).unwrap();
    let snap = sess.prefix_snapshot(&mut eng).unwrap();
    let prompt_positions = "the capital of ".len() + 1; // + BOS
    for (s, t) in snap.stage_caches.iter().enumerate() {
        assert_eq!(
            t.shape[2],
            prompt_positions - 1,
            "stage {s}: snapshot not sliced to the live prefix"
        );
        assert!(t.shape[2] < max_seq, "stage {s}: full-capacity copy");
    }
    assert_eq!(snap.positions(), prompt_positions - 1);
}

/// The observability acceptance bar: on the shared-prefix workload at
/// max_concurrent 4, fused groups form and decode steps per XLA
/// dispatch exceed 1 (N live sessions no longer cost N dispatch
/// rounds).
#[test]
fn fused_groups_form_under_load() {
    if !have_artifacts() {
        return;
    }
    let man = Manifest::load_config(&artifacts_root(), "ee-tiny").unwrap();
    let state = trained_state(&man, 60);
    let corpus = Corpus::build(&CorpusSpec {
        seed: 7,
        n_entities: 8,
        target_bytes: 120_000,
    });
    let spec = SharedPrefixSpec {
        seed: 11,
        n_groups: 2,
        requests_per_group: 6,
        prefix_bytes: man.model.max_seq / 2,
    };
    let reqs: Vec<ServeRequest> =
        shared_prefix_prompts(&spec, &corpus.facts)
            .into_iter()
            .enumerate()
            .map(|(i, p)| ServeRequest::new(i as u64, p, 8))
            .collect();
    let (_, out) = pooled_streams(
        &state,
        ExitPolicy::confidence(0.6),
        reqs,
        4,
        true,
        0,
    );
    let l = &out.metrics.lanes;
    assert!(l.fused_calls > 0, "no fused calls: {l:?}");
    assert!(
        l.steps_per_dispatch() > 1.0,
        "steps per dispatch {:.2} <= 1 at max_concurrent 4: {l:?}",
        l.steps_per_dispatch()
    );
    assert!(
        l.occupancy.iter().any(|&(w, _)| w >= 2),
        "no multi-lane occupancy recorded: {l:?}"
    );
}

/// Session-level equivalence, no pool in the way: four sessions stepped
/// through `step_fused` produce exactly the streams of four sessions
/// stepped solo, and their final outputs (stats included) agree.
#[test]
fn step_fused_equals_solo_stepping() {
    if !have_artifacts() {
        return;
    }
    let man = Manifest::load_config(&artifacts_root(), "ee-tiny").unwrap();
    let state = trained_state(&man, 60);
    for policy in [
        ExitPolicy::confidence(0.2),
        ExitPolicy::confidence(0.7),
        ExitPolicy::Never,
    ] {
        let mut eng =
            SequentialEngine::new(state.clone(), policy.clone()).unwrap();
        assert!(
            !DecodeBackend::decode_lanes(&eng).is_empty(),
            "engine loaded no lane executables"
        );
        let prompts = &PROMPTS[..4];
        // Solo reference streams.
        let mut want = Vec::new();
        for p in prompts {
            want.push(serial_stream(&mut eng, p, 10));
        }
        // Fused: the same four prompts as concurrent sessions. Sessions
        // drop out as they finish; un-fusable rounds (deficit healing)
        // step solo, exactly like the pool.
        let mut sessions: Vec<(usize, DecodeSession)> = prompts
            .iter()
            .enumerate()
            .map(|(i, p)| {
                let mut s =
                    DecodeSession::new_text(&mut eng, p, 10).unwrap();
                s.prefill(&mut eng).unwrap();
                (i, s)
            })
            .collect();
        let mut got: Vec<Vec<(i32, usize)>> = vec![Vec::new(); 4];
        let lanes: Vec<usize> = DecodeBackend::decode_lanes(&eng).to_vec();
        while !sessions.is_empty() {
            let fusable: Vec<bool> = sessions
                .iter()
                .map(|(_, s)| s.fusable(&eng))
                .collect();
            let n_fusable = fusable.iter().filter(|&&f| f).count();
            let width = lanes
                .iter()
                .copied()
                .filter(|&b| b <= n_fusable)
                .max();
            if let Some(width) = width {
                let mut group: Vec<&mut DecodeSession> = Vec::new();
                let mut ids = Vec::new();
                for ((id, s), &f) in sessions.iter_mut().zip(&fusable) {
                    if f && group.len() < width {
                        ids.push(*id);
                        group.push(s);
                    }
                }
                let fused =
                    DecodeSession::step_fused(&mut eng, &mut group)
                        .unwrap();
                for (id, ev) in ids.iter().zip(fused.events) {
                    if let StepEvent::Token { token, exit_layer, .. } = ev
                    {
                        got[*id].push((token, exit_layer));
                    }
                }
            }
            // Everyone not fused this round steps solo (deficit heals,
            // leftovers).
            let fused_now: std::collections::BTreeSet<usize> = {
                let width = width.unwrap_or(0);
                sessions
                    .iter()
                    .zip(&fusable)
                    .filter(|(_, &f)| f)
                    .map(|((id, _), _)| *id)
                    .take(width)
                    .collect()
            };
            for (id, s) in sessions.iter_mut() {
                if fused_now.contains(id) || s.is_done() {
                    continue;
                }
                if let StepEvent::Token { token, exit_layer, .. } =
                    s.step(&mut eng).unwrap()
                {
                    got[*id].push((token, exit_layer));
                }
            }
            sessions.retain(|(_, s)| !s.is_done());
        }
        for (i, (g, w)) in got.iter().zip(&want).enumerate() {
            assert!(!w.is_empty());
            assert_eq!(
                g, w,
                "policy {policy}, prompt {i}: fused stepping diverged \
                 from solo"
            );
        }
    }
}
