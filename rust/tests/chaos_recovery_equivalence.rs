//! Integration: self-healing serving must be **output-invisible**.
//!
//! A pinned-seed chaos schedule ([`FaultPlan`]) fires injected faults at
//! every serving seam — fused dispatch, interleaved submit/collect
//! windows, solo decode, stage-thread panics, snapshot/restore, prefix
//! restore — while decode-time micro-checkpoints plus bounded-retry
//! recovery re-admit every casualty. The bar: on both engines, across
//! exit policies, with the prefix cache on and off, every request under
//! chaos completes with a (token, exit-layer) stream **identical** to
//! its fault-free run; retries stay within budget; the recovery ledger
//! balances (`recoveries + recovery_failures == observed_total()`); and
//! bursty multi-tenant traffic under chaos terminates with zero
//! deadlocks and zero dropped requests.

use std::collections::BTreeMap;
use std::path::PathBuf;
use std::time::Duration;

use eellm::config::{LossWeightSchedule, LrSchedule};
use eellm::data::dataset::{Dataset, TrainBatch};
use eellm::data::synth::{bursty_traffic, Corpus, CorpusSpec, TrafficSpec};
use eellm::inference::{ExitPolicy, ModelState};
use eellm::runtime::artifacts::Manifest;
use eellm::serve::{
    BatchOutcome, ControlConfig, EngineKind, EnginePool, FaultPlan,
    FaultSite, HealConfig, Outcome, Policy, PoolConfig, ServeEvent,
    ServeRequest,
};
use eellm::training::trainer::{PipelineTrainer, TrainerOptions};

fn artifacts_root() -> PathBuf {
    PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts")
}

fn have_artifacts() -> bool {
    let ok = artifacts_root().join("ee-tiny").join("manifest.json").is_file();
    if !ok {
        eprintln!("skipping: run `make artifacts`");
    }
    ok
}

/// Train ee-tiny briefly so confidences are meaningful (same recipe as
/// the sibling equivalence suites).
fn trained_state(man: &Manifest, steps: usize) -> ModelState {
    let corpus = Corpus::build(&CorpusSpec {
        seed: 7,
        n_entities: 8,
        target_bytes: 120_000,
    });
    let mut ds =
        Dataset::from_corpus(&corpus, man.model.seq, man.model.microbatch, 3);
    let mut trainer = PipelineTrainer::new(
        man.clone(),
        TrainerOptions {
            seed: 42,
            lr: LrSchedule::cosine(3e-3, 5, steps),
            grad_clip: 1.0,
            loss_weights: LossWeightSchedule::Constant,
            total_steps: steps,
            bubble_fill: 0,
            bf_ratio: 2.0,
        },
    )
    .unwrap();
    for _ in 0..steps {
        let batches: Vec<TrainBatch> =
            (0..2).map(|_| ds.next_microbatch()).collect();
        trainer.train_step(&batches, &[]).unwrap();
    }
    let params = trainer.params().unwrap();
    trainer.shutdown();
    ModelState { man: man.clone(), stage_params: params }
}

/// The per-request recovery budget used across this suite — generous
/// enough that exhausting it under the pinned rates is statistically
/// implausible, small enough that `retries <= MAX_RETRIES` is a real
/// boundedness assertion.
const MAX_RETRIES: u32 = 12;

/// A 1-worker pool with self-healing on: micro-checkpoints every 2
/// tokens, bounded retries with a fast backoff, and a quarantine bar
/// set far above any plausible flap count so chaos exercises rebuilds,
/// not abandonment.
fn heal_cfg(
    engine: EngineKind,
    policy: ExitPolicy,
    cache_positions: usize,
    lane_fusion: bool,
    chaos: Option<FaultPlan>,
) -> PoolConfig {
    PoolConfig {
        workers: 1,
        engine,
        policy,
        sched: Policy::Fifo,
        max_concurrent: 2,
        prefix_cache_positions: cache_positions,
        device_tier_positions: 0,
        convo_idle_ttl: Duration::from_secs(300),
        lane_fusion,
        lane_residency: false,
        control: ControlConfig {
            heal: HealConfig {
                checkpoint_interval: 2,
                checkpoint_capacity: 8,
                max_retries: MAX_RETRIES,
                backoff: Duration::from_millis(1),
                quarantine_after: 32,
                chaos,
            },
            ..ControlConfig::default()
        },
    }
}

/// Run a streamed batch on its own thread with a watchdog, collecting
/// each request's (token, exit layer) stream: recovery bugs must
/// surface as typed failures or diverged streams, never as a hung
/// completion loop.
fn run_streamed(
    state: ModelState,
    cfg: PoolConfig,
    reqs: Vec<ServeRequest>,
) -> (BatchOutcome, BTreeMap<u64, Vec<(i32, usize)>>) {
    let (tx, rx) = std::sync::mpsc::channel();
    let h = std::thread::spawn(move || {
        let mut pool = EnginePool::new(state, cfg);
        let mut streams: BTreeMap<u64, Vec<(i32, usize)>> = BTreeMap::new();
        let out = pool
            .run_batch_streamed(reqs, |ev| {
                if let ServeEvent::Token { id, token, exit_layer, .. } = ev {
                    streams
                        .entry(*id)
                        .or_default()
                        .push((*token, *exit_layer));
                }
            })
            .expect("batch");
        pool.shutdown().expect("shutdown");
        let _ = tx.send((out, streams));
    });
    let got = rx
        .recv_timeout(Duration::from_secs(180))
        .expect("pool deadlocked under chaos injection");
    h.join().unwrap();
    got
}

/// Every request completed, stayed within its retry budget, and the
/// recovery ledger balances: each observed failure episode closed with
/// exactly one recovery or one give-up.
fn assert_healed(out: &BatchOutcome, n: usize, label: &str) {
    assert!(out.failures.is_empty(), "{label}: {:?}", out.failures);
    assert!(out.sheds.is_empty(), "{label}: {:?}", out.sheds);
    assert_eq!(out.responses.len(), n, "{label}: dropped requests");
    let f = &out.metrics.faults;
    assert_eq!(
        f.recoveries + f.recovery_failures,
        f.observed_total(),
        "{label}: recovery ledger out of balance: {f:?}"
    );
    assert_eq!(
        f.recovery_failures, 0,
        "{label}: a request gave up without a typed failure: {f:?}"
    );
    for r in &out.responses {
        assert!(
            r.retries <= MAX_RETRIES,
            "{label}: retry budget overrun on id {}: {} > {MAX_RETRIES}",
            r.id,
            r.retries
        );
    }
}

/// Three of these share the `"fact: the capital "` prefix so cache-on
/// runs exercise genuine prefix restores under chaos.
const PROMPTS: [&str; 6] = [
    "fact: the capital of freedonia is ",
    "fact: the capital of sylvania is ",
    "fact: the capital city ",
    "count: 3 4 5 ",
    "abc: a b c d ",
    "the color of ",
];

fn prompt_reqs(max_new: usize) -> Vec<ServeRequest> {
    PROMPTS
        .iter()
        .enumerate()
        .map(|(i, p)| ServeRequest::new(i as u64, *p, max_new))
        .collect()
}

/// The headline bar: under pinned-seed uniform chaos at every fault
/// site, recovered streams are token- and exit-layer-identical to the
/// fault-free run — on both engines, across >= 3 exit policies
/// (including the `Never` full-model baseline), with the prefix cache
/// on and off.
#[test]
fn chaos_recovered_streams_match_fault_free_runs() {
    if !have_artifacts() {
        return;
    }
    let man = Manifest::load_config(&artifacts_root(), "ee-tiny").unwrap();
    let state = trained_state(&man, 60);
    let policies = [
        ExitPolicy::confidence(0.4),
        ExitPolicy::Never,
        ExitPolicy::Entropy { max_nats: 1.0 },
    ];
    let cache_budget = 8 * man.model.max_seq;
    let mut injected_anywhere = 0u64;
    for &engine in &[EngineKind::Sequential, EngineKind::Pipelined] {
        for policy in &policies {
            for &cache in &[0usize, cache_budget] {
                let label = format!(
                    "{engine:?}/{policy}/cache={}",
                    if cache > 0 { "on" } else { "off" }
                );
                let (ref_out, want) = run_streamed(
                    state.clone(),
                    heal_cfg(engine, policy.clone(), cache, true, None),
                    prompt_reqs(10),
                );
                assert!(
                    ref_out.failures.is_empty(),
                    "{label}: fault-free reference run failed: {:?}",
                    ref_out.failures
                );
                assert_eq!(
                    ref_out.metrics.faults.injected_total(),
                    0,
                    "{label}: chaos-off run injected faults"
                );
                let chaos =
                    FaultPlan::new(0xC0FFEE).with_uniform_rate(0.05);
                let (out, got) = run_streamed(
                    state.clone(),
                    heal_cfg(
                        engine,
                        policy.clone(),
                        cache,
                        true,
                        Some(chaos),
                    ),
                    prompt_reqs(10),
                );
                assert_healed(&out, PROMPTS.len(), &label);
                assert_eq!(
                    got, want,
                    "{label}: recovered streams diverged from the \
                     fault-free run"
                );
                injected_anywhere += out.metrics.faults.injected_total();
            }
        }
    }
    assert!(
        injected_anywhere > 0,
        "uniform 5% chaos never fired across the whole matrix — the \
         injector is dead and the suite proved nothing"
    );
}

/// Micro-checkpoints make recovery cheap and observable: under a
/// decode-site-only schedule on solo steps, failed sessions re-admit
/// from their latest checkpoint, the already-streamed head is
/// suppressed on replay (counted as `redecoded_tokens`), and the
/// stitched stream still matches the fault-free run.
#[test]
fn micro_checkpoints_bound_the_redecoded_tail() {
    if !have_artifacts() {
        return;
    }
    let man = Manifest::load_config(&artifacts_root(), "ee-tiny").unwrap();
    let state = trained_state(&man, 60);
    let policy = ExitPolicy::confidence(0.4);
    // lane_fusion off: every step is a solo decode, so the `decode`
    // fault site sees every token draw.
    let (ref_out, want) = run_streamed(
        state.clone(),
        heal_cfg(EngineKind::Sequential, policy.clone(), 0, false, None),
        prompt_reqs(16),
    );
    assert!(ref_out.failures.is_empty(), "{:?}", ref_out.failures);
    let chaos = FaultPlan::new(7).with_rate(FaultSite::Decode, 0.12);
    let (out, got) = run_streamed(
        state.clone(),
        heal_cfg(
            EngineKind::Sequential,
            policy,
            0,
            false,
            Some(chaos),
        ),
        prompt_reqs(16),
    );
    assert_healed(&out, PROMPTS.len(), "sequential/solo");
    assert_eq!(
        got, want,
        "checkpoint-recovered streams diverged from the fault-free run"
    );
    let f = &out.metrics.faults;
    assert!(
        f.observed[FaultSite::Decode.index()] > 0,
        "12% decode chaos never fired over ~96 solo steps: {f:?}"
    );
    assert!(f.recoveries > 0, "faults fired but nothing recovered: {f:?}");
    assert!(
        f.checkpoints > 0,
        "a 2-token checkpoint cadence captured nothing: {f:?}"
    );
    assert!(
        f.redecoded_tokens > 0,
        "recoveries re-admitted sessions without replaying any \
         suppressed head — checkpoint restore never engaged: {f:?}"
    );
    // Only the tail is re-decoded: replayed work stays well under the
    // batch's total output (scratch re-decodes would blow past it).
    let total_tokens: u64 =
        want.values().map(|s| s.len() as u64).sum();
    assert!(
        f.redecoded_tokens < total_tokens,
        "re-decoded {} of {total_tokens} tokens — recovery is replaying \
         whole streams, not checkpoint tails: {f:?}",
        f.redecoded_tokens
    );
}

/// Bursty multi-tenant traffic through the full control plane
/// (priority scheduling + preemption) under uniform chaos: the batch
/// terminates with zero deadlocks, zero dropped requests, and every
/// request resolved to a typed non-failure outcome, on both engines.
#[test]
fn bursty_chaos_drops_nothing_and_terminates() {
    if !have_artifacts() {
        return;
    }
    let man = Manifest::load_config(&artifacts_root(), "ee-tiny").unwrap();
    let state = trained_state(&man, 60);
    let corpus = Corpus::build(&CorpusSpec {
        seed: 7,
        n_entities: 8,
        target_bytes: 120_000,
    });
    let spec = TrafficSpec {
        seed: 13,
        n_requests: 12,
        tenants: vec![3.0, 1.0],
        period: 6,
        burst_len: 3,
        deadline_ms: (20, 200),
        deadline_rate: 0.6,
        max_new: (2, 6),
        prompt_bytes: (16, 64),
    };
    let traffic = bursty_traffic(&spec, &corpus.facts);
    let reqs: Vec<ServeRequest> = traffic
        .iter()
        .enumerate()
        .map(|(i, t)| {
            let mut r =
                ServeRequest::new(i as u64, t.prompt.as_str(), t.max_new)
                    .with_priority(t.priority)
                    .with_tenant(t.tenant);
            if let Some(ms) = t.deadline_ms {
                r = r.with_deadline(Duration::from_millis(ms));
            }
            r
        })
        .collect();
    for &engine in &[EngineKind::Sequential, EngineKind::Pipelined] {
        let mut cfg = heal_cfg(
            engine,
            ExitPolicy::confidence(0.4),
            0,
            true,
            Some(FaultPlan::new(29).with_uniform_rate(0.03)),
        );
        cfg.sched = Policy::Priority;
        cfg.control.preempt = true;
        cfg.control.preempt_horizon = Duration::from_secs(60);
        cfg.control.park_capacity = 2;
        cfg.control.tenant_weights = spec.tenants.clone();
        let (out, _) = run_streamed(state.clone(), cfg, reqs.clone());
        assert_healed(&out, 12, &format!("{engine:?}/bursty"));
        let outcomes = out.outcomes();
        assert_eq!(outcomes.len(), 12, "{engine:?}");
        for (i, o) in outcomes.iter().enumerate() {
            assert_eq!(o.id(), i as u64, "{engine:?}");
            assert!(
                !matches!(o, Outcome::Failed(_)),
                "{engine:?}: request {i} failed under recoverable \
                 chaos: {o:?}"
            );
        }
    }
}
