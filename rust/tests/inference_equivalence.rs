//! Integration: the two early-exit inference methods (KV recomputation and
//! pipeline-based) must generate the same outputs (paper Appendix B.1), and
//! both must match the full-model baseline when the threshold is 1.
//!
//! Uses a briefly-trained ee-tiny model so that confidences are meaningful
//! (an untrained model has near-uniform logits and ties everywhere).

use std::path::PathBuf;

use eellm::config::{LossWeightSchedule, LrSchedule};
use eellm::data::dataset::{Dataset, TrainBatch};
use eellm::data::synth::{Corpus, CorpusSpec};
use eellm::inference::{
    ExitPolicy, ModelState, PipelinedEngine, SequentialEngine,
};
use eellm::runtime::artifacts::Manifest;
use eellm::serve::{
    ControlConfig, EngineKind, EnginePool, Policy, PoolConfig, ServeEvent,
    ServeRequest,
};
use eellm::training::trainer::{PipelineTrainer, TrainerOptions};

fn artifacts_root() -> PathBuf {
    PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts")
}

/// Train ee-tiny briefly and return its parameters.
fn trained_state(man: &Manifest, steps: usize) -> ModelState {
    let corpus = Corpus::build(&CorpusSpec {
        seed: 7,
        n_entities: 8,
        target_bytes: 120_000,
    });
    let mut ds =
        Dataset::from_corpus(&corpus, man.model.seq, man.model.microbatch, 3);
    let mut trainer = PipelineTrainer::new(
        man.clone(),
        TrainerOptions {
            seed: 42,
            lr: LrSchedule::cosine(3e-3, 5, steps),
            grad_clip: 1.0,
            loss_weights: LossWeightSchedule::Constant,
            total_steps: steps,
            bubble_fill: 0,
            bf_ratio: 2.0,
        },
    )
    .unwrap();
    for _ in 0..steps {
        let batches: Vec<TrainBatch> =
            (0..2).map(|_| ds.next_microbatch()).collect();
        trainer.train_step(&batches, &[]).unwrap();
    }
    let params = trainer.params().unwrap();
    trainer.shutdown();
    ModelState { man: man.clone(), stage_params: params }
}

#[test]
fn engines_agree_and_early_exits_fire() {
    if !artifacts_root().join("ee-tiny").join("manifest.json").is_file() {
        eprintln!("skipping: run `make artifacts`");
        return;
    }
    let man = Manifest::load_config(&artifacts_root(), "ee-tiny").unwrap();
    let state = trained_state(&man, 60);

    let prompts = [
        "the capital of ",
        "question: what is the ",
        "count: 3 4 5 ",
        "abc: a b c d ",
    ];

    // --- threshold = 1.0: both engines are the full model; outputs must
    // match token-for-token, and every token must use the final exit.
    let mut seq = SequentialEngine::new(state.clone(), ExitPolicy::confidence(1.0)).unwrap();
    let mut pipe = PipelinedEngine::new(state.clone(), ExitPolicy::confidence(1.0)).unwrap();
    for p in &prompts {
        let a = seq.generate_text(p, 16).unwrap();
        let b = pipe.generate_text(p, 16).unwrap();
        assert_eq!(a.tokens, b.tokens, "prompt {p:?}: {} vs {}", a.text, b.text);
        assert_eq!(a.stats.early_fraction(man.model.n_layers), 0.0);
        assert_eq!(b.stats.early_fraction(man.model.n_layers), 0.0);
        assert!(!a.tokens.is_empty());
    }

    // --- low threshold: the paper's claim (Appendix B.1) is that KV
    // recomputation and the pipeline-based method generate the same
    // output for the same prompt.
    // After only 60 steps the early exit tops out near conf ~0.23 (see
    // examples/probe_check.rs); tau = 0.2 exercises real early exits while
    // the equivalence claim stays the assertion under test.
    let tau = 0.2f32;
    let mut seq = SequentialEngine::new(state.clone(), ExitPolicy::confidence(tau)).unwrap();
    pipe.set_policy(ExitPolicy::confidence(tau));
    let mut early_total = 0.0;
    let mut n = 0.0;
    for p in &prompts {
        let a = seq.generate_text(p, 16).unwrap();
        let b = pipe.generate_text(p, 16).unwrap();
        assert_eq!(
            a.tokens, b.tokens,
            "prompt {p:?}: recompute {:?} vs pipelined {:?}",
            a.text, b.text
        );
        early_total += a.stats.early_fraction(man.model.n_layers);
        n += 1.0;
    }
    // With tau = 0.5 on a trained model, at least some tokens must exit
    // early somewhere across the prompt set.
    assert!(
        early_total / n > 0.0,
        "no early exits fired at tau={tau}"
    );
    pipe.shutdown();
}

#[test]
fn recompute_deficit_respects_cap_and_heals() {
    if !artifacts_root().join("ee-tiny").join("manifest.json").is_file() {
        eprintln!("skipping: run `make artifacts`");
        return;
    }
    let man = Manifest::load_config(&artifacts_root(), "ee-tiny").unwrap();
    // Untrained params + threshold 0.0: *every* token exits at the first
    // early exit, driving the deficit into the cap continuously.
    let state = ModelState::init(man.clone(), 5);
    let mut eng = SequentialEngine::new(state, ExitPolicy::confidence(0.0)).unwrap();
    let out = eng.generate_text("hello world", 24).unwrap();
    assert!(out.tokens.len() >= 8, "{out:?}");
    // Early exits fired...
    assert!(out.stats.early_fraction(man.model.n_layers) > 0.5, "{out:?}");
    // ...and the cap forced periodic full passes (widths are 1,2,4,8: the
    // deficit can grow to at most 7 before a forced full pass).
    assert!(out.stats.forced_full > 0, "{out:?}");
}

#[test]
fn generation_is_deterministic() {
    if !artifacts_root().join("ee-tiny").join("manifest.json").is_file() {
        eprintln!("skipping: run `make artifacts`");
        return;
    }
    let man = Manifest::load_config(&artifacts_root(), "ee-tiny").unwrap();
    let state = ModelState::init(man, 11);
    let mut eng = SequentialEngine::new(state.clone(), ExitPolicy::confidence(0.7)).unwrap();
    let a = eng.generate_text("abc: a b", 12).unwrap();
    let b = eng.generate_text("abc: a b", 12).unwrap();
    assert_eq!(a.tokens, b.tokens);
    let mut eng2 = SequentialEngine::new(state, ExitPolicy::confidence(0.7)).unwrap();
    let c = eng2.generate_text("abc: a b", 12).unwrap();
    assert_eq!(a.tokens, c.tokens);
}

/// Cross-engine equivalence under the serving layer: at threshold 1.0, N
/// concurrent requests through the pool must produce byte-identical
/// outputs to the same prompts run serially through `SequentialEngine`.
#[test]
fn pooled_serving_matches_serial_at_threshold_one() {
    if !artifacts_root().join("ee-tiny").join("manifest.json").is_file() {
        eprintln!("skipping: run `make artifacts`");
        return;
    }
    let man = Manifest::load_config(&artifacts_root(), "ee-tiny").unwrap();
    let state = ModelState::init(man.clone(), 9);
    let prompts = [
        "the capital of ",
        "question: what is the ",
        "count: 3 4 5 ",
        "abc: a b c d ",
        "copy: x y |",
        "3+4=",
    ];

    // Serial baseline through one SequentialEngine.
    let mut seq = SequentialEngine::new(state.clone(), ExitPolicy::confidence(1.0)).unwrap();
    let serial: Vec<Vec<i32>> = prompts
        .iter()
        .map(|p| seq.generate_text(p, 12).unwrap().tokens)
        .collect();

    for &workers in &[2usize, 4] {
        let mut pool = EnginePool::new(
            state.clone(),
            PoolConfig {
                workers,
                engine: EngineKind::Sequential,
                policy: ExitPolicy::confidence(1.0),
                // SPF shuffles completion order relative to submission,
                // exercising the id-based reordering.
                sched: Policy::ShortestPromptFirst,
                max_concurrent: 2,
                prefix_cache_positions: 0,
                device_tier_positions: 0,
                convo_idle_ttl: std::time::Duration::from_secs(300),
                lane_fusion: false,
                lane_residency: true,
                control: ControlConfig::default(),
            },
        );
        let reqs: Vec<ServeRequest> = prompts
            .iter()
            .enumerate()
            .map(|(i, p)| ServeRequest::new(i as u64, *p, 12))
            .collect();
        let out = pool.run_batch(reqs).unwrap();
        pool.shutdown().unwrap();
        assert!(out.failures.is_empty(), "{:?}", out.failures);
        let responses = &out.responses;
        assert_eq!(responses.len(), prompts.len());
        for (i, r) in responses.iter().enumerate() {
            assert_eq!(r.id, i as u64);
            assert_eq!(
                r.output.tokens, serial[i],
                "prompt {:?} diverged under pooled serving (pool {workers})",
                prompts[i]
            );
            assert!(r.total_seconds >= r.queue_seconds);
            assert!(r.ttft_seconds >= r.queue_seconds);
            assert!(r.ttft_seconds <= r.total_seconds + 1e-9);
            assert_eq!(r.token_seconds.len(), r.output.tokens.len());
        }
        // Threshold 1.0: every token comes from the final exit.
        assert_eq!(out.metrics.early_fraction(man.model.n_layers), 0.0);
        assert!(out.metrics.total_tokens > 0);
        assert!(out.metrics.throughput_tps() > 0.0);
    }
}

/// Continuous batching: one worker interleaving sessions must (a) stream
/// byte-identical tokens to serial generation at threshold 1.0, (b) start
/// decoding a second request before the first finishes (TTFT well below
/// the first request's completion), and (c) admit requests queued beyond
/// the concurrency cap mid-flight, not at batch close.
#[test]
fn continuous_batching_streams_and_admits_mid_flight() {
    if !artifacts_root().join("ee-tiny").join("manifest.json").is_file() {
        eprintln!("skipping: run `make artifacts`");
        return;
    }
    let man = Manifest::load_config(&artifacts_root(), "ee-tiny").unwrap();
    let state = ModelState::init(man.clone(), 9);

    // Pick prompts whose serial generations are long enough to overlap.
    let candidates = [
        "the capital of ",
        "question: what is the ",
        "count: 3 4 5 ",
        "abc: a b c d ",
        "copy: x y |",
        "3+4=",
    ];
    let mut seq = SequentialEngine::new(state.clone(), ExitPolicy::confidence(1.0)).unwrap();
    let long: Vec<&str> = candidates
        .iter()
        .copied()
        .filter(|p| seq.generate_text(p, 12).unwrap().tokens.len() >= 4)
        .take(3)
        .collect();
    if long.len() < 3 {
        eprintln!("skipping: generations too short to interleave");
        return;
    }
    // Request 0 is short (budget 2) so it finishes while request 1 (>= 4
    // tokens) is still live, freeing a slot for request 2 mid-flight.
    let budgets = [2usize, 12, 12];
    let serial: Vec<Vec<i32>> = long
        .iter()
        .zip(budgets)
        .map(|(p, b)| seq.generate_text(p, b).unwrap().tokens)
        .collect();

    let mut pool = EnginePool::new(
        state,
        PoolConfig {
            workers: 1,
            engine: EngineKind::Sequential,
            policy: ExitPolicy::confidence(1.0),
            sched: Policy::Fifo,
            max_concurrent: 2,
            prefix_cache_positions: 0,
            device_tier_positions: 0,
            convo_idle_ttl: std::time::Duration::from_secs(300),
            lane_fusion: false,
            lane_residency: true,
            control: ControlConfig::default(),
        },
    );
    let reqs: Vec<ServeRequest> = long
        .iter()
        .zip(budgets)
        .enumerate()
        .map(|(i, (p, b))| ServeRequest::new(i as u64, *p, b))
        .collect();
    let mut events: Vec<ServeEvent> = Vec::new();
    let out = pool
        .run_batch_streamed(reqs, |e| events.push(e.clone()))
        .unwrap();
    pool.shutdown().unwrap();
    assert!(out.failures.is_empty(), "{:?}", out.failures);
    assert_eq!(out.responses.len(), 3);

    // (a) Streamed tokens are byte-identical to serial generation.
    for (i, expect) in serial.iter().enumerate() {
        let streamed: Vec<i32> = events
            .iter()
            .filter_map(|e| match e {
                ServeEvent::Token { id, token, .. } if *id == i as u64 => {
                    Some(*token)
                }
                _ => None,
            })
            .collect();
        assert_eq!(
            &streamed, expect,
            "request {i} streamed tokens diverge from serial"
        );
        assert_eq!(&out.responses[i].output.tokens, expect);
    }

    let first_token = |id: u64| {
        events
            .iter()
            .position(|e| matches!(e, ServeEvent::Token { id: i, .. } if *i == id))
            .unwrap_or_else(|| panic!("no token for request {id}"))
    };
    let done_of = |id: u64| {
        events
            .iter()
            .position(|e| matches!(e, ServeEvent::Done { id: i } if *i == id))
            .unwrap_or_else(|| panic!("no done for request {id}"))
    };

    // (b) Concurrent decode on one worker: request 1 starts before
    // request 0 finishes, and its time-to-first-token lands before the
    // first request's completion.
    assert!(
        first_token(1) < done_of(0),
        "request 1 did not start before request 0 finished: {events:?}"
    );
    assert!(
        out.responses[1].ttft_seconds < out.responses[0].total_seconds,
        "TTFT of the second request ({}) should precede the first \
         request's completion ({})",
        out.responses[1].ttft_seconds,
        out.responses[0].total_seconds
    );

    // (c) Mid-flight admission: request 2 (queued beyond the concurrency
    // cap) starts decoding while request 1 is still generating.
    assert!(
        first_token(2) < done_of(1),
        "request 2 was not admitted mid-flight: {events:?}"
    );

    // Stream timing is populated and ordered sanely.
    for r in &out.responses {
        assert_eq!(r.token_seconds.len(), r.output.tokens.len());
        assert!(r.ttft_seconds > 0.0);
        assert!(r.ttft_seconds <= r.total_seconds + 1e-9);
    }
    assert!(out.metrics.p95_ttft_seconds >= out.metrics.p50_ttft_seconds);
}

/// Regression (batch poisoning): one failing request must not wipe out
/// the other responses of its batch — failures are reported per request.
#[test]
fn batch_reports_per_request_failures() {
    if !artifacts_root().join("ee-tiny").join("manifest.json").is_file() {
        eprintln!("skipping: run `make artifacts`");
        return;
    }
    let man = Manifest::load_config(&artifacts_root(), "ee-tiny").unwrap();
    let state = ModelState::init(man.clone(), 4);
    // A prompt longer than the KV cache fails at session setup.
    let poisoned = "a".repeat(man.model.max_seq + 8);
    let reqs = vec![
        ServeRequest::new(0, "abc: a b", 8),
        ServeRequest::new(1, poisoned, 8),
        ServeRequest::new(2, "count: 1 2 ", 8),
    ];
    let mut pool = EnginePool::new(
        state,
        PoolConfig {
            workers: 1,
            engine: EngineKind::Sequential,
            policy: ExitPolicy::confidence(1.0),
            sched: Policy::Fifo,
            max_concurrent: 2,
            prefix_cache_positions: 0,
            device_tier_positions: 0,
            convo_idle_ttl: std::time::Duration::from_secs(300),
            lane_fusion: false,
            lane_residency: true,
            control: ControlConfig::default(),
        },
    );
    let out = pool.run_batch(reqs).unwrap();
    pool.shutdown().unwrap();
    assert_eq!(out.responses.len(), 2, "good requests must survive");
    assert_eq!(out.responses[0].id, 0);
    assert_eq!(out.responses[1].id, 2);
    assert!(!out.responses[0].output.tokens.is_empty());
    assert_eq!(out.failures.len(), 1);
    assert_eq!(out.failures[0].id, 1);
    assert_eq!(out.failures[0].worker, Some(0));
    assert!(
        out.failures[0].error.contains("exceeds"),
        "unexpected error: {}",
        out.failures[0].error
    );
    assert_eq!(out.metrics.requests, 2);
}

/// Regression (over-strict capacity check): a prompt that fits must
/// generate as many tokens as the KV cache allows instead of erroring
/// when `prompt + max_new` exceeds capacity; an over-long prompt still
/// errors.
#[test]
fn capacity_clamps_instead_of_erroring() {
    if !artifacts_root().join("ee-tiny").join("manifest.json").is_file() {
        eprintln!("skipping: run `make artifacts`");
        return;
    }
    let man = Manifest::load_config(&artifacts_root(), "ee-tiny").unwrap();
    let max_seq = man.model.max_seq;
    let state = ModelState::init(man.clone(), 4);
    // Prompt of max_seq - 4 bytes => max_seq - 3 tokens with BOS, leaving
    // room for exactly 3 generated tokens.
    let prompt = "a".repeat(max_seq - 4);
    let too_long = "a".repeat(max_seq + 8);

    let mut seq = SequentialEngine::new(state.clone(), ExitPolicy::confidence(1.0)).unwrap();
    let out = seq.generate_text(&prompt, 100).unwrap();
    assert!(
        (1..=3).contains(&out.tokens.len()),
        "expected 1..=3 clamped tokens, got {}",
        out.tokens.len()
    );
    assert!(seq.generate_text(&too_long, 4).is_err());

    let mut pipe = PipelinedEngine::new(state, ExitPolicy::confidence(1.0)).unwrap();
    let out = pipe.generate_text(&prompt, 100).unwrap();
    assert!(
        (1..=3).contains(&out.tokens.len()),
        "expected 1..=3 clamped tokens, got {}",
        out.tokens.len()
    );
    assert!(pipe.generate_text(&too_long, 4).is_err());
    pipe.shutdown();
}

#[test]
fn probe_reports_all_exits_per_token() {
    if !artifacts_root().join("ee-tiny").join("manifest.json").is_file() {
        eprintln!("skipping: run `make artifacts`");
        return;
    }
    let man = Manifest::load_config(&artifacts_root(), "ee-tiny").unwrap();
    let state = ModelState::init(man.clone(), 3);
    let report =
        eellm::inference::probe::probe_generation(state, "hello", 6).unwrap();
    assert!(!report.probes.is_empty());
    for p in &report.probes {
        // ee-tiny: one early exit (layer 2) + final (layer 4).
        assert_eq!(p.exits.len(), 2, "{p:?}");
        assert_eq!(p.exits[0].0, 2);
        assert_eq!(p.exits[1].0, 4);
        for e in &p.exits {
            assert!(e.2 > 0.0 && e.2 <= 1.0);
        }
    }
    let table = report.to_table();
    assert!(table.to_markdown().contains("conf@2"));
}
