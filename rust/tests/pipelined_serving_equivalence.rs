//! Integration: multi-session pipelined serving must be
//! **output-invisible**.
//!
//! Interleaving many decode sessions down the pipelined engine's stage
//! chain (one session's deep-stage KV back-fill overlapping another's
//! shallow-stage forward) must produce token-for-token and
//! exit-layer-for-exit-layer the same streams as serial pipelined
//! decoding and as the sequential engine — across exit policies
//! (including the `Confidence{1.0}` and `Never` full-model baselines),
//! with the prefix KV cache on or off, and under mid-flight admission.
//! The overlap claim is separate and observable: a pipelined pool at
//! `max_concurrent` >= 2 must record interleaved rounds with >= 2
//! sessions in flight ([`ServeMetrics::interleave`] occupancy).
//!
//! [`ServeMetrics::interleave`]: eellm::serve::ServeMetrics

use std::collections::BTreeMap;
use std::path::PathBuf;

use eellm::config::{LossWeightSchedule, LrSchedule};
use eellm::data::dataset::{Dataset, TrainBatch};
use eellm::data::synth::{
    shared_prefix_prompts, Corpus, CorpusSpec, SharedPrefixSpec,
};
use eellm::inference::{
    DecodeBackend, DecodeSession, ExitPolicy, ModelState, PipelinedEngine,
    PrefixCacheStore, SequentialEngine, StepEvent,
};
use eellm::runtime::artifacts::Manifest;
use eellm::serve::{
    BatchOutcome, ControlConfig, EngineKind, EnginePool, Policy,
    PoolConfig, ServeEvent, ServeRequest,
};
use eellm::training::trainer::{PipelineTrainer, TrainerOptions};

fn artifacts_root() -> PathBuf {
    PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts")
}

fn have_artifacts() -> bool {
    let ok = artifacts_root().join("ee-tiny").join("manifest.json").is_file();
    if !ok {
        eprintln!("skipping: run `make artifacts`");
    }
    ok
}

/// Train ee-tiny briefly so confidences are meaningful (same recipe as
/// the sibling equivalence suites).
fn trained_state(man: &Manifest, steps: usize) -> ModelState {
    let corpus = Corpus::build(&CorpusSpec {
        seed: 7,
        n_entities: 8,
        target_bytes: 120_000,
    });
    let mut ds =
        Dataset::from_corpus(&corpus, man.model.seq, man.model.microbatch, 3);
    let mut trainer = PipelineTrainer::new(
        man.clone(),
        TrainerOptions {
            seed: 42,
            lr: LrSchedule::cosine(3e-3, 5, steps),
            grad_clip: 1.0,
            loss_weights: LossWeightSchedule::Constant,
            total_steps: steps,
            bubble_fill: 0,
            bf_ratio: 2.0,
        },
    )
    .unwrap();
    for _ in 0..steps {
        let batches: Vec<TrainBatch> =
            (0..2).map(|_| ds.next_microbatch()).collect();
        trainer.train_step(&batches, &[]).unwrap();
    }
    let params = trainer.params().unwrap();
    trainer.shutdown();
    ModelState { man: man.clone(), stage_params: params }
}

type Streams = BTreeMap<u64, Vec<(i32, usize)>>;

/// Serve `reqs` on a one-worker pool of `engine` workers and collect
/// each request's (token, exit layer) stream from the live event feed.
fn pooled_streams(
    state: &ModelState,
    engine: EngineKind,
    policy: ExitPolicy,
    reqs: Vec<ServeRequest>,
    max_concurrent: usize,
    prefix_cache_positions: usize,
) -> (Streams, BatchOutcome) {
    let mut pool = EnginePool::new(
        state.clone(),
        PoolConfig {
            workers: 1,
            engine,
            policy,
            sched: Policy::Fifo,
            max_concurrent,
            prefix_cache_positions,
            device_tier_positions: 0,
            convo_idle_ttl: std::time::Duration::from_secs(300),
            lane_fusion: true,
            lane_residency: true,
            control: ControlConfig::default(),
        },
    );
    let mut streams: Streams = BTreeMap::new();
    let out = pool
        .run_batch_streamed(reqs, |ev| {
            if let ServeEvent::Token { id, token, exit_layer, .. } = ev {
                streams.entry(*id).or_default().push((*token, *exit_layer));
            }
        })
        .unwrap();
    pool.shutdown().unwrap();
    assert!(out.failures.is_empty(), "{:?}", out.failures);
    (streams, out)
}

/// Drain one serial session, collecting its (token, exit layer) stream.
fn serial_stream(
    backend: &mut dyn DecodeBackend,
    prompt: &str,
    max_new: usize,
) -> Vec<(i32, usize)> {
    let mut s = DecodeSession::new_text(backend, prompt, max_new).unwrap();
    s.prefill(backend).unwrap();
    let mut out = Vec::new();
    while !s.is_done() {
        if let StepEvent::Token { token, exit_layer, .. } =
            s.step(backend).unwrap()
        {
            out.push((token, exit_layer));
        }
    }
    s.close(backend);
    out
}

const PROMPTS: [&str; 6] = [
    "the capital of ",
    "question: what is the ",
    "count: 3 4 5 ",
    "abc: a b c d ",
    "the color of ",
    "fact: the capital ",
];

/// The acceptance grid: interleaved pipelined pool streams equal the
/// serial (`max_concurrent` 1) pipelined pool, the serial pipelined
/// engine, and the sequential engine, across >= 3 exit policies
/// including the `Confidence{1.0}` and `Never` full-model baselines —
/// and the interleaved runs demonstrably overlap >= 2 sessions in
/// flight.
#[test]
fn interleaved_pool_matches_serial_pipelined_and_sequential() {
    if !have_artifacts() {
        return;
    }
    let man = Manifest::load_config(&artifacts_root(), "ee-tiny").unwrap();
    let state = trained_state(&man, 60);
    let policies = [
        ExitPolicy::confidence(0.4),
        ExitPolicy::confidence(1.0),
        ExitPolicy::Never,
        ExitPolicy::Entropy { max_nats: 1.0 },
    ];
    for policy in &policies {
        let reqs: Vec<ServeRequest> = PROMPTS
            .iter()
            .enumerate()
            .map(|(i, p)| ServeRequest::new(i as u64, *p, 10))
            .collect();
        let (interleaved, m_on) = pooled_streams(
            &state,
            EngineKind::Pipelined,
            policy.clone(),
            reqs.clone(),
            4,
            0,
        );
        let (serial_pool, m_serial) = pooled_streams(
            &state,
            EngineKind::Pipelined,
            policy.clone(),
            reqs,
            1,
            0,
        );
        assert_eq!(
            interleaved, serial_pool,
            "policy {policy}: interleaved pipelined pool diverged from \
             the serial pipelined pool"
        );
        let mut pipe =
            PipelinedEngine::new(state.clone(), policy.clone()).unwrap();
        let mut seq =
            SequentialEngine::new(state.clone(), policy.clone()).unwrap();
        for (i, p) in PROMPTS.iter().enumerate() {
            let want = serial_stream(&mut pipe, p, 10);
            assert!(!want.is_empty(), "policy {policy}: empty stream");
            assert_eq!(
                interleaved[&(i as u64)],
                want,
                "policy {policy}, prompt {p:?}: interleaved pool diverged \
                 from the serial pipelined engine"
            );
            assert_eq!(
                serial_stream(&mut seq, p, 10),
                want,
                "policy {policy}, prompt {p:?}: pipelined diverged from \
                 sequential"
            );
        }
        pipe.shutdown();
        // The overlap acceptance bar: >= 2 sessions demonstrably in
        // flight on the chain at max_concurrent 4.
        let il = &m_on.metrics.interleave;
        assert!(il.rounds > 0, "policy {policy}: no interleaved rounds");
        assert!(
            il.occupancy.iter().any(|&(n, _)| n >= 2),
            "policy {policy}: no round held >= 2 sessions in flight: \
             {il:?}"
        );
        assert!(il.max_in_flight() >= 2, "policy {policy}: {il:?}");
        // The serial pool never overlaps — the histogram says so.
        assert!(
            m_serial
                .metrics
                .interleave
                .occupancy
                .iter()
                .all(|&(n, _)| n == 1),
            "serial pool recorded overlap: {:?}",
            m_serial.metrics.interleave
        );
    }
}

/// Prefix KV reuse on the pipelined engine: cache-on streams equal
/// cache-off streams (and the sequential engine's cache-on streams),
/// with real hits — the capability carve-out is gone end to end.
#[test]
fn prefix_cache_parity_on_pipelined_pool() {
    if !have_artifacts() {
        return;
    }
    let man = Manifest::load_config(&artifacts_root(), "ee-tiny").unwrap();
    let state = trained_state(&man, 60);
    let max_seq = man.model.max_seq;
    let corpus = Corpus::build(&CorpusSpec {
        seed: 7,
        n_entities: 8,
        target_bytes: 120_000,
    });
    let spec = SharedPrefixSpec {
        seed: 11,
        n_groups: 2,
        requests_per_group: 4,
        prefix_bytes: max_seq / 2,
    };
    let prompts = shared_prefix_prompts(&spec, &corpus.facts);
    let reqs: Vec<ServeRequest> = prompts
        .iter()
        .enumerate()
        .map(|(i, p)| ServeRequest::new(i as u64, p.as_str(), 8))
        .collect();
    let policy = ExitPolicy::confidence(0.6);
    let mut all: Vec<Streams> = Vec::new();
    for &engine in &[EngineKind::Pipelined, EngineKind::Sequential] {
        for &budget in &[0usize, 8 * max_seq] {
            let (streams, out) = pooled_streams(
                &state,
                engine,
                policy.clone(),
                reqs.clone(),
                4,
                budget,
            );
            if budget > 0 {
                assert!(
                    out.metrics.prefix.hits > 0,
                    "{engine:?}: no prefix hits on shared prompts"
                );
                assert!(
                    out.metrics.prefill_positions_saved() > 0,
                    "{engine:?}: prefix hits saved no prefill positions"
                );
            }
            all.push(streams);
        }
    }
    for s in &all[1..] {
        assert_eq!(
            *s, all[0],
            "streams diverged across engine x prefix-cache combinations"
        );
    }
}

/// Snapshots cross engines: a prefix snapshot drained from the
/// pipelined engine's stage chain restores on the sequential engine and
/// vice versa, with identical continuations — the host snapshot format
/// is engine-agnostic.
#[test]
fn snapshots_roundtrip_across_engines() {
    if !have_artifacts() {
        return;
    }
    let man = Manifest::load_config(&artifacts_root(), "ee-tiny").unwrap();
    let state = trained_state(&man, 60);
    let policy = ExitPolicy::confidence(0.6);
    let prompt = "fact: the capital of freedonia is ";
    let budget = 8 * man.model.max_seq;
    let mut pipe =
        PipelinedEngine::new(state.clone(), policy.clone()).unwrap();
    assert!(
        DecodeBackend::supports_cache_snapshots(&pipe),
        "the pipelined engine must support cache snapshots"
    );
    let mut seq =
        SequentialEngine::new(state.clone(), policy.clone()).unwrap();
    let want = serial_stream(&mut pipe, prompt, 8);
    assert_eq!(want, serial_stream(&mut seq, prompt, 8));

    fn roundtrip(
        donor: &mut dyn DecodeBackend,
        restorer: &mut dyn DecodeBackend,
        prompt: &str,
        budget: usize,
        want: &[(i32, usize)],
    ) {
        let store = PrefixCacheStore::new(budget);
        let mut d = DecodeSession::new_text(donor, prompt, 8).unwrap();
        d.prefill(donor).unwrap();
        assert!(store.insert(d.prefix_snapshot(donor).unwrap()));
        d.close(donor);
        let mut r =
            DecodeSession::new_text(restorer, prompt, 8).unwrap();
        let rep = r.prefill_with_cache(restorer, &store).unwrap();
        assert!(
            rep.cached_tokens > 0 && rep.saved_positions > 0,
            "restore missed: {rep:?}"
        );
        let mut got = Vec::new();
        while !r.is_done() {
            if let StepEvent::Token { token, exit_layer, .. } =
                r.step(restorer).unwrap()
            {
                got.push((token, exit_layer));
            }
        }
        r.close(restorer);
        assert_eq!(
            got, want,
            "cross-engine restored continuation diverged"
        );
    }
    // Pipelined snapshot -> sequential restore, and the reverse.
    roundtrip(&mut pipe, &mut seq, prompt, budget, &want);
    roundtrip(&mut seq, &mut pipe, prompt, budget, &want);
    pipe.shutdown();
}

/// Mid-flight admission on the pipelined pool: more requests than live
/// slots with staggered budgets, so sessions open on the chain while
/// earlier ones are mid-generation. Streams must equal the serial
/// pipelined pool exactly.
#[test]
fn mid_flight_admission_matches_serial_pipelined() {
    if !have_artifacts() {
        return;
    }
    let man = Manifest::load_config(&artifacts_root(), "ee-tiny").unwrap();
    let state = trained_state(&man, 60);
    let reqs: Vec<ServeRequest> = (0..10)
        .map(|i| {
            let p = PROMPTS[i % PROMPTS.len()];
            // Varied budgets stagger completions, forcing admissions
            // into partially-drained rounds.
            ServeRequest::new(i as u64, p, 6 + (i % 5))
        })
        .collect();
    let policy = ExitPolicy::confidence(0.4);
    let (on, m_on) = pooled_streams(
        &state,
        EngineKind::Pipelined,
        policy.clone(),
        reqs.clone(),
        3,
        0,
    );
    let (serial, _) =
        pooled_streams(&state, EngineKind::Pipelined, policy, reqs, 1, 0);
    assert_eq!(on, serial, "mid-flight admission diverged on the chain");
    assert!(
        m_on.metrics.interleave.occupancy.iter().any(|&(n, _)| n >= 2),
        "no overlap under churn: {:?}",
        m_on.metrics.interleave
    );
}

/// Mixed per-request policies interleave on one chain: each session's
/// policy is captured stage-side at admission, so mixed-policy rounds
/// never leak policies across sessions — and the engine-resident policy
/// is only swapped at admission, never per round.
#[test]
fn mixed_policy_sessions_share_the_chain() {
    if !have_artifacts() {
        return;
    }
    let man = Manifest::load_config(&artifacts_root(), "ee-tiny").unwrap();
    let state = trained_state(&man, 60);
    let policies = [
        ExitPolicy::confidence(0.6),
        ExitPolicy::Never,
        ExitPolicy::confidence(0.6),
        ExitPolicy::confidence(0.2),
        ExitPolicy::Never,
        ExitPolicy::confidence(0.6),
    ];
    let reqs: Vec<ServeRequest> = PROMPTS
        .iter()
        .zip(&policies)
        .enumerate()
        .map(|(i, (p, pol))| {
            ServeRequest::new(i as u64, *p, 10).with_policy(pol.clone())
        })
        .collect();
    // Pool default differs from every request: a leak shows up as a
    // diverged stream.
    let default = ExitPolicy::confidence(0.9);
    let (on, m_on) = pooled_streams(
        &state,
        EngineKind::Pipelined,
        default.clone(),
        reqs.clone(),
        6,
        0,
    );
    let (serial, _) =
        pooled_streams(&state, EngineKind::Pipelined, default, reqs, 1, 0);
    assert_eq!(on, serial, "mixed-policy interleaving diverged");
    for (i, (p, pol)) in PROMPTS.iter().zip(&policies).enumerate() {
        let mut engine =
            PipelinedEngine::new(state.clone(), pol.clone()).unwrap();
        let want = serial_stream(&mut engine, p, 10);
        engine.shutdown();
        assert_eq!(
            on[&(i as u64)],
            want,
            "request {i} (policy {pol}) diverged from serial"
        );
    }
    // Interleaved rounds never swap the engine-resident policy; swaps
    // are bounded by admissions, not decode steps.
    let il = &m_on.metrics.interleave;
    assert!(
        m_on.metrics.lanes.policy_applies <= PROMPTS.len() as u64,
        "per-round policy churn on the chain: {} applies over {} rounds",
        m_on.metrics.lanes.policy_applies,
        il.rounds
    );
    assert!(
        il.occupancy.iter().any(|&(n, _)| n >= 2),
        "mixed-policy sessions never overlapped: {il:?}"
    );
}
