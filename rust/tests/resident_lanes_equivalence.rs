//! Integration: device-resident lane groups must be **output-invisible**
//! and must actually kill the per-step host round-trip.
//!
//! The resident fused path (`lane_residency` on, the default) keeps each
//! lane group's stage caches on device across rounds and scatters them
//! back only at departures — lane exits, regroups, snapshots, or solo
//! windows. This suite pins both halves of that claim:
//!
//! * equivalence — resident pooled streams equal the round-trip pool
//!   (`lane_residency: false`, the PR-5 gather/scatter baseline) and solo
//!   decoding token-for-token and exit-layer-for-exit-layer, across exit
//!   policies, mid-flight admission with lane exits mid-group, and every
//!   lanes x prefix-cache combination;
//! * traffic — warm rounds move zero lane-cache bytes (the engine's
//!   [`LaneTraffic`] deltas are exactly zero at steady state), cold
//!   formation pays one gather per lane per stage, and a departure pays
//!   one scatter per parked lane per stage, nothing per step.

use std::collections::BTreeMap;
use std::path::PathBuf;

use eellm::config::{LossWeightSchedule, LrSchedule};
use eellm::data::dataset::{Dataset, TrainBatch};
use eellm::data::synth::{
    shared_prefix_prompts, Corpus, CorpusSpec, SharedPrefixSpec,
};
use eellm::inference::{
    DecodeBackend, DecodeSession, ExitPolicy, FusedStep, LaneTraffic,
    ModelState, SequentialEngine, StepEvent,
};
use eellm::runtime::artifacts::Manifest;
use eellm::serve::{
    BatchOutcome, ControlConfig, EngineKind, EnginePool, Policy,
    PoolConfig, ServeEvent, ServeRequest,
};
use eellm::training::trainer::{PipelineTrainer, TrainerOptions};

fn artifacts_root() -> PathBuf {
    PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts")
}

fn have_artifacts() -> bool {
    let ok = artifacts_root().join("ee-tiny").join("manifest.json").is_file();
    if !ok {
        eprintln!("skipping: run `make artifacts`");
    }
    ok
}

/// Train ee-tiny briefly so confidences are meaningful (same recipe as
/// the sibling equivalence suites).
fn trained_state(man: &Manifest, steps: usize) -> ModelState {
    let corpus = Corpus::build(&CorpusSpec {
        seed: 7,
        n_entities: 8,
        target_bytes: 120_000,
    });
    let mut ds =
        Dataset::from_corpus(&corpus, man.model.seq, man.model.microbatch, 3);
    let mut trainer = PipelineTrainer::new(
        man.clone(),
        TrainerOptions {
            seed: 42,
            lr: LrSchedule::cosine(3e-3, 5, steps),
            grad_clip: 1.0,
            loss_weights: LossWeightSchedule::Constant,
            total_steps: steps,
            bubble_fill: 0,
            bf_ratio: 2.0,
        },
    )
    .unwrap();
    for _ in 0..steps {
        let batches: Vec<TrainBatch> =
            (0..2).map(|_| ds.next_microbatch()).collect();
        trainer.train_step(&batches, &[]).unwrap();
    }
    let params = trainer.params().unwrap();
    trainer.shutdown();
    ModelState { man: man.clone(), stage_params: params }
}

type Streams = BTreeMap<u64, Vec<(i32, usize)>>;

/// Serve `reqs` on a one-worker lane-fused pool and collect each
/// request's (token, exit layer) stream, toggling cache residency.
fn pooled_streams(
    state: &ModelState,
    policy: ExitPolicy,
    reqs: Vec<ServeRequest>,
    max_concurrent: usize,
    lane_residency: bool,
    prefix_cache_positions: usize,
) -> (Streams, BatchOutcome) {
    let mut pool = EnginePool::new(
        state.clone(),
        PoolConfig {
            workers: 1,
            engine: EngineKind::Sequential,
            policy,
            sched: Policy::Fifo,
            max_concurrent,
            prefix_cache_positions,
            device_tier_positions: 0,
            convo_idle_ttl: std::time::Duration::from_secs(300),
            lane_fusion: true,
            lane_residency,
            control: ControlConfig::default(),
        },
    );
    let mut streams: Streams = BTreeMap::new();
    let out = pool
        .run_batch_streamed(reqs, |ev| {
            if let ServeEvent::Token { id, token, exit_layer, .. } = ev {
                streams.entry(*id).or_default().push((*token, *exit_layer));
            }
        })
        .unwrap();
    pool.shutdown().unwrap();
    assert!(out.failures.is_empty(), "{:?}", out.failures);
    (streams, out)
}

/// Drain one serial session, collecting its (token, exit layer) stream.
fn serial_stream(
    backend: &mut dyn DecodeBackend,
    prompt: &str,
    max_new: usize,
) -> Vec<(i32, usize)> {
    let mut s = DecodeSession::new_text(backend, prompt, max_new).unwrap();
    s.prefill(backend).unwrap();
    let mut out = Vec::new();
    while !s.is_done() {
        if let StepEvent::Token { token, exit_layer, .. } =
            s.step(backend).unwrap()
        {
            out.push((token, exit_layer));
        }
    }
    out
}

const PROMPTS: [&str; 6] = [
    "the capital of ",
    "question: what is the ",
    "count: 3 4 5 ",
    "abc: a b c d ",
    "the color of ",
    "fact: the capital ",
];

/// The acceptance grid: resident pooled streams equal the round-trip
/// pool and serial decoding across >= 3 exit policies, and the traffic
/// counters split exactly as designed — the round-trip pool pays a
/// gather per fused step and never forms a resident group; the resident
/// pool's gathers are bounded by group formations, not steps.
#[test]
fn resident_matches_roundtrip_and_serial_across_policies() {
    if !have_artifacts() {
        return;
    }
    let man = Manifest::load_config(&artifacts_root(), "ee-tiny").unwrap();
    assert!(
        !man.decode_lanes.is_empty(),
        "ee-tiny manifest lists no decode_lanes; rebuild artifacts"
    );
    let state = trained_state(&man, 60);
    let stages = man.stages.len() as u64;
    let max_lane = *man.decode_lanes.iter().max().unwrap() as u64;
    let policies = [
        ExitPolicy::confidence(0.2),
        ExitPolicy::confidence(0.6),
        ExitPolicy::Never,
        ExitPolicy::Entropy { max_nats: 1.0 },
    ];
    for policy in &policies {
        let reqs: Vec<ServeRequest> = PROMPTS
            .iter()
            .enumerate()
            .map(|(i, p)| ServeRequest::new(i as u64, *p, 12))
            .collect();
        let (res, m_res) = pooled_streams(
            &state,
            policy.clone(),
            reqs.clone(),
            4,
            true,
            0,
        );
        let (rt, m_rt) =
            pooled_streams(&state, policy.clone(), reqs, 4, false, 0);
        assert_eq!(
            res, rt,
            "policy {policy}: resident pool diverged from round-trip"
        );
        let mut serial =
            SequentialEngine::new(state.clone(), policy.clone()).unwrap();
        for (i, p) in PROMPTS.iter().enumerate() {
            let want = serial_stream(&mut serial, p, 12);
            assert!(!want.is_empty(), "policy {policy}: empty stream");
            assert_eq!(
                res[&(i as u64)],
                want,
                "policy {policy}, prompt {p:?}: resident pool diverged \
                 from serial"
            );
        }
        // Traffic split. Resident: cache gathers happen at group
        // formation only (<= forms x lanes x stages), never per step.
        let l = &m_res.metrics.lanes;
        assert!(l.fused_steps > 0, "policy {policy}: no fused steps");
        assert!(
            l.cold_group_forms > 0,
            "policy {policy}: fused steps without a group formation: {l:?}"
        );
        assert!(
            l.cache_gathers <= l.cold_group_forms * max_lane * stages,
            "policy {policy}: resident gathers {} exceed formation bound \
             ({} forms x {max_lane} lanes x {stages} stages): {l:?}",
            l.cache_gathers,
            l.cold_group_forms
        );
        // Round-trip: every fused step re-gathers its lanes; residency
        // counters stay at zero.
        let l = &m_rt.metrics.lanes;
        assert!(l.fused_steps > 0, "policy {policy}: no round-trip fusion");
        assert_eq!(
            (l.warm_group_hits, l.cold_group_forms),
            (0, 0),
            "policy {policy}: round-trip pool formed resident groups: {l:?}"
        );
        assert!(
            l.cache_gathers >= l.fused_steps,
            "policy {policy}: round-trip gathers {} below fused steps {} \
             (baseline must pay per step): {l:?}",
            l.cache_gathers,
            l.fused_steps
        );
        // Group stickiness under a policy that never breaks groups: the
        // same members re-fuse round after round and hit warm.
        if !policy.may_exit() {
            let l = &m_res.metrics.lanes;
            assert!(
                l.warm_group_hits > 0,
                "policy {policy}: no warm hits despite stable groups: {l:?}"
            );
        }
    }
}

/// Mid-flight admission with lane exits mid-group: more requests than
/// live slots and an exit-happy policy, so lanes fire at stage entries,
/// depart with a deficit, heal solo, and regroup — the maximum-churn
/// path for resident group dissolution. Streams must equal the
/// round-trip pool exactly.
#[test]
fn admission_churn_and_exits_match_roundtrip() {
    if !have_artifacts() {
        return;
    }
    let man = Manifest::load_config(&artifacts_root(), "ee-tiny").unwrap();
    let state = trained_state(&man, 60);
    let reqs: Vec<ServeRequest> = (0..10)
        .map(|i| {
            let p = PROMPTS[i % PROMPTS.len()];
            // Varied budgets stagger completions, forcing admissions
            // into partially-drained rounds.
            ServeRequest::new(i as u64, p, 6 + (i % 5))
        })
        .collect();
    let policy = ExitPolicy::confidence(0.4);
    let (res, m_res) =
        pooled_streams(&state, policy.clone(), reqs.clone(), 3, true, 0);
    let (rt, _) = pooled_streams(&state, policy, reqs, 3, false, 0);
    assert_eq!(res, rt, "admission churn diverged under residency");
    assert!(m_res.metrics.lanes.fused_steps > 0, "no fusion under churn");
}

/// Prefix-cache interaction: snapshot restores seed sessions that then
/// join resident groups, and post-prefill snapshots read through any
/// group the session sits in (dissolve-on-snapshot). All four
/// (residency x cache) combinations produce identical streams.
#[test]
fn prefix_cache_and_residency_compose() {
    if !have_artifacts() {
        return;
    }
    let man = Manifest::load_config(&artifacts_root(), "ee-tiny").unwrap();
    let state = trained_state(&man, 60);
    let max_seq = man.model.max_seq;
    let corpus = Corpus::build(&CorpusSpec {
        seed: 7,
        n_entities: 8,
        target_bytes: 120_000,
    });
    let spec = SharedPrefixSpec {
        seed: 11,
        n_groups: 2,
        requests_per_group: 4,
        prefix_bytes: max_seq / 2,
    };
    let prompts = shared_prefix_prompts(&spec, &corpus.facts);
    let reqs: Vec<ServeRequest> = prompts
        .iter()
        .enumerate()
        .map(|(i, p)| ServeRequest::new(i as u64, p.as_str(), 8))
        .collect();
    let policy = ExitPolicy::confidence(0.6);
    let mut all: Vec<Streams> = Vec::new();
    for &residency in &[false, true] {
        for &budget in &[0usize, 8 * max_seq] {
            let (streams, out) = pooled_streams(
                &state,
                policy.clone(),
                reqs.clone(),
                4,
                residency,
                budget,
            );
            if budget > 0 {
                assert!(
                    out.metrics.prefix.hits > 0,
                    "residency {residency}: no prefix hits on shared \
                     prompts"
                );
            }
            all.push(streams);
        }
    }
    for s in &all[1..] {
        assert_eq!(
            *s, all[0],
            "streams diverged across residency x prefix-cache combinations"
        );
    }
}

/// Step exactly the sessions at `pick` (ascending) as one fused group.
fn step_group(
    eng: &mut SequentialEngine,
    sessions: &mut [DecodeSession],
    pick: &[usize],
) -> FusedStep {
    let mut group: Vec<&mut DecodeSession> = sessions
        .iter_mut()
        .enumerate()
        .filter(|(i, _)| pick.contains(i))
        .map(|(_, s)| s)
        .collect();
    DecodeSession::step_fused(eng, &mut group).unwrap()
}

/// The tentpole's traffic contract, pinned round by round on a bare
/// engine: cold formation pays one gather per lane per stage; warm
/// rounds move **zero** cache bytes; a departure (here: a lane running
/// out of budget, shrinking the group) pays one scatter per parked lane
/// per stage when the survivors re-form; solo windows over parked lanes
/// are free (host-side moves, no device traffic).
#[test]
fn warm_rounds_move_zero_traffic_and_departures_scatter_once() {
    if !have_artifacts() {
        return;
    }
    let man = Manifest::load_config(&artifacts_root(), "ee-tiny").unwrap();
    if !man.decode_lanes.contains(&4) || !man.decode_lanes.contains(&2) {
        eprintln!("skipping: ee-tiny lanes lack widths 2 and 4");
        return;
    }
    let state = trained_state(&man, 60);
    let stages = man.stages.len() as u64;
    // Bytes of one lane's full stage-cache set (f32).
    let lane_bytes: u64 = man
        .stages
        .iter()
        .map(|st| st.cache_shape.iter().product::<usize>() as u64 * 4)
        .sum();
    // `Never` keeps every lane fusable (no exits, no deficit), so group
    // membership changes only when a session exhausts its budget.
    let mut eng =
        SequentialEngine::new(state, ExitPolicy::Never).unwrap();
    assert!(eng.lane_residency, "residency must default on");

    // Session 0 gets a 3-token budget so it departs after round 3;
    // the rest outlive the test.
    let mut sessions: Vec<DecodeSession> = PROMPTS[..4]
        .iter()
        .enumerate()
        .map(|(i, p)| {
            let max_new = if i == 0 { 3 } else { 8 };
            let mut s =
                DecodeSession::new_text(&mut eng, p, max_new).unwrap();
            s.prefill(&mut eng).unwrap();
            s
        })
        .collect();
    // Prefill runs solo windows on never-resident handles: no traffic.
    let mut base = DecodeBackend::lane_traffic(&eng);
    assert_eq!(base, LaneTraffic::default(), "prefill moved cache bytes");

    // Round 1: cold formation — one gather per lane per stage, nothing
    // scattered.
    step_group(&mut eng, &mut sessions, &[0, 1, 2, 3]);
    let d = DecodeBackend::lane_traffic(&eng).since(&base);
    assert_eq!(d.cold_forms, 1, "first fused round must form a group");
    assert_eq!(d.warm_hits, 0);
    assert_eq!(d.cache_gathers, 4 * stages, "formation gathers: {d:?}");
    assert_eq!(d.gather_bytes, 4 * lane_bytes, "formation bytes: {d:?}");
    assert_eq!(d.cache_scatters, 0, "formation must not scatter: {d:?}");
    base = DecodeBackend::lane_traffic(&eng);

    // Rounds 2-3: warm steady state — zero cache traffic, per round.
    for round in 2..=3 {
        step_group(&mut eng, &mut sessions, &[0, 1, 2, 3]);
        let d = DecodeBackend::lane_traffic(&eng).since(&base);
        assert_eq!(d.warm_hits, 1, "round {round} missed warm: {d:?}");
        assert_eq!(
            (d.cache_gathers, d.cache_scatters, d.gather_bytes,
             d.scatter_bytes, d.cold_forms),
            (0, 0, 0, 0, 0),
            "round {round} moved cache traffic at steady state: {d:?}"
        );
        base = DecodeBackend::lane_traffic(&eng);
    }
    assert!(sessions[0].is_done(), "session 0 should exhaust its budget");

    // Departure: the 4-group cannot re-form (3 survivors, lane ladder
    // has no 3), so sessions 1+2 re-form as a pair. Forming it dissolves
    // the stale 4-group — one scatter per parked lane per stage, once,
    // not per step — then gathers the pair.
    step_group(&mut eng, &mut sessions, &[1, 2]);
    let d = DecodeBackend::lane_traffic(&eng).since(&base);
    assert_eq!(d.cold_forms, 1, "pair must cold-form: {d:?}");
    assert_eq!(
        d.cache_scatters,
        4 * stages,
        "dissolving the stale group scatters each member once: {d:?}"
    );
    assert_eq!(d.scatter_bytes, 4 * lane_bytes, "departure bytes: {d:?}");
    assert_eq!(d.cache_gathers, 2 * stages, "pair gathers: {d:?}");
    base = DecodeBackend::lane_traffic(&eng);

    // The left-over survivor steps solo from its parked literals:
    // host-side moves only, no gather/scatter traffic.
    if let StepEvent::Token { .. } = sessions[3].step(&mut eng).unwrap() {
    } else {
        panic!("survivor solo step emitted no token");
    }
    let d = DecodeBackend::lane_traffic(&eng).since(&base);
    assert_eq!(
        (d.cache_gathers, d.cache_scatters),
        (0, 0),
        "solo window over parked caches moved device traffic: {d:?}"
    );
    base = DecodeBackend::lane_traffic(&eng);

    // And the pair is warm again: steady state restored.
    step_group(&mut eng, &mut sessions, &[1, 2]);
    let d = DecodeBackend::lane_traffic(&eng).since(&base);
    assert_eq!(d.warm_hits, 1, "pair should re-hit warm: {d:?}");
    assert_eq!(
        (d.cache_gathers, d.cache_scatters),
        (0, 0),
        "post-departure steady state moved cache traffic: {d:?}"
    );
}
