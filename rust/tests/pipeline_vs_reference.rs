//! Integration: the pipeline-parallel trainer must reproduce the
//! monolithic reference model exactly (Proposition 3.1), end-to-end over
//! real PJRT executables and the multi-thread 1F1B runtime.

use std::path::PathBuf;

use eellm::config::{LossWeightSchedule, LrSchedule};
use eellm::data::dataset::{Dataset, TrainBatch};
use eellm::data::synth::{Corpus, CorpusSpec};
use eellm::runtime::artifacts::Manifest;
use eellm::runtime::params;
use eellm::runtime::tensor::HostTensor;
use eellm::training::reference::ReferenceModel;
use eellm::training::trainer::{PipelineTrainer, TrainerOptions};

fn artifacts_root() -> PathBuf {
    PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts")
}

fn have_artifacts(name: &str) -> bool {
    artifacts_root().join(name).join("manifest.json").is_file()
}

fn dataset_for(man: &Manifest, seed: u64) -> Dataset {
    let corpus = Corpus::build(&CorpusSpec {
        seed,
        n_entities: 8,
        target_bytes: 60_000,
    });
    Dataset::from_corpus(&corpus, man.model.seq, man.model.microbatch, seed)
}

fn opts(steps: usize) -> TrainerOptions {
    TrainerOptions {
        seed: 42,
        lr: LrSchedule::constant(1e-3),
        grad_clip: 0.0,
        loss_weights: LossWeightSchedule::Constant,
        total_steps: steps,
        bubble_fill: 0,
        bf_ratio: 2.0,
    }
}

/// Average of per-microbatch reference losses & grads — what one pipeline
/// step (which accumulates over microbatches) must equal.
fn reference_step(
    reference: &ReferenceModel,
    batches: &[TrainBatch],
    weights: &[f32],
) -> (Vec<f64>, Vec<HostTensor>) {
    let mut losses = vec![0f64; weights.len()];
    let mut grads: Option<Vec<HostTensor>> = None;
    for b in batches {
        let (l, g) = reference.loss_grads(b, weights).unwrap();
        for (i, v) in l.iter().enumerate() {
            losses[i] += v;
        }
        match &mut grads {
            None => grads = Some(g),
            Some(acc) => {
                for (a, t) in acc.iter_mut().zip(&g) {
                    a.axpy(1.0, &t);
                }
            }
        }
    }
    let m = batches.len() as f64;
    for l in &mut losses {
        *l /= m;
    }
    let mut grads = grads.unwrap();
    for g in &mut grads {
        g.scale(1.0 / m as f32);
    }
    (losses, grads)
}

#[test]
fn pipeline_losses_match_reference_exactly() {
    if !have_artifacts("ee-tiny") {
        eprintln!("skipping: run `make artifacts`");
        return;
    }
    let man = Manifest::load_config(&artifacts_root(), "ee-tiny").unwrap();
    let mut ds = dataset_for(&man, 7);
    let batches: Vec<TrainBatch> =
        (0..4).map(|_| ds.next_microbatch()).collect();

    let reference = ReferenceModel::new(man.clone(), 42).unwrap();
    let weights = reference.default_weights();
    let (ref_losses, _) = reference_step(&reference, &batches, &weights);

    let mut trainer = PipelineTrainer::new(man, opts(10)).unwrap();
    let stats = trainer.train_step(&batches, &[]).unwrap();
    trainer.shutdown();

    assert_eq!(stats.losses.len(), ref_losses.len());
    for (a, b) in stats.losses.iter().zip(&ref_losses) {
        assert!(
            (a - b).abs() < 1e-5,
            "pipeline {a} vs reference {b} (all: {:?} vs {:?})",
            stats.losses,
            ref_losses
        );
    }
}

#[test]
fn pipeline_validation_matches_reference_eval() {
    if !have_artifacts("ee-tiny") {
        eprintln!("skipping: run `make artifacts`");
        return;
    }
    let man = Manifest::load_config(&artifacts_root(), "ee-tiny").unwrap();
    let mut ds = dataset_for(&man, 9);
    let batches: Vec<TrainBatch> =
        (0..2).map(|_| ds.next_microbatch()).collect();

    let reference = ReferenceModel::new(man.clone(), 42).unwrap();
    let weights = reference.default_weights();
    let mut want = vec![0f64; weights.len()];
    for b in &batches {
        let (_, l) = reference.eval(b, &weights).unwrap();
        for (i, v) in l.iter().enumerate() {
            want[i] += v / batches.len() as f64;
        }
    }

    let mut trainer = PipelineTrainer::new(man, opts(10)).unwrap();
    let got = trainer.validate(&batches).unwrap();
    trainer.shutdown();
    for (a, b) in got.iter().zip(&want) {
        assert!((a - b).abs() < 1e-5, "{got:?} vs {want:?}");
    }
}

#[test]
fn one_training_step_matches_reference_adam_update() {
    // Run one pipeline train step, then verify the *parameters* moved
    // exactly as a host-side Adam with the reference gradients dictates.
    if !have_artifacts("ee-tiny") {
        eprintln!("skipping: run `make artifacts`");
        return;
    }
    let man = Manifest::load_config(&artifacts_root(), "ee-tiny").unwrap();
    let mut ds = dataset_for(&man, 21);
    let batches: Vec<TrainBatch> =
        (0..2).map(|_| ds.next_microbatch()).collect();

    let reference = ReferenceModel::new(man.clone(), 42).unwrap();
    let weights = reference.default_weights();
    let (_, ref_grads) = reference_step(&reference, &batches, &weights);

    let lr = 1e-3f64;
    let mut trainer = PipelineTrainer::new(man.clone(), opts(10)).unwrap();
    let before = params::init_full(42, &man);
    trainer.train_step(&batches, &[]).unwrap();
    let after_stage = trainer.params().unwrap();
    trainer.shutdown();
    let after: Vec<HostTensor> = after_stage.into_iter().flatten().collect();

    // Host-side Adam step 1: m = (1-b1)g, v = (1-b2)g^2,
    // update = (m/(1-b1)) / (sqrt(v/(1-b2)) + eps) = g/(|g|+eps).
    let (b1, b2, eps) = (0.9f64, 0.95f64, 1e-8f64);
    let mut max_err = 0f64;
    for ((p0, g), p1) in before.iter().zip(&ref_grads).zip(&after) {
        for i in 0..p0.data.len() {
            let g = g.data[i] as f64;
            let m = (1.0 - b1) * g;
            let v = (1.0 - b2) * g * g;
            let upd = (m / (1.0 - b1)) / ((v / (1.0 - b2)).sqrt() + eps);
            let want = p0.data[i] as f64 - lr * upd;
            let got = p1.data[i] as f64;
            max_err = max_err.max((got - want).abs());
        }
    }
    // Tolerance note: at step 1 Adam's update is ~ g/(|g|+eps), which is
    // sensitive to f32 accumulation-order noise for |g| near zero; the
    // bound is ~15% of one LR step, far below any systematic error.
    assert!(max_err < 1.5e-4, "max param err {max_err}");
}

#[test]
fn tied_embeddings_stay_synchronized() {
    if !have_artifacts("ee-tiny-tied") {
        eprintln!("skipping: run `make artifacts`");
        return;
    }
    let man =
        Manifest::load_config(&artifacts_root(), "ee-tiny-tied").unwrap();
    let groups = man.tie_groups();
    let members = groups.get("unembed").unwrap().clone();
    assert!(members.len() >= 2);

    let mut ds = dataset_for(&man, 33);
    let mut trainer = PipelineTrainer::new(man, opts(10)).unwrap();
    for _ in 0..3 {
        let batches: Vec<TrainBatch> =
            (0..2).map(|_| ds.next_microbatch()).collect();
        trainer.train_step(&batches, &[]).unwrap();
    }
    let params = trainer.params().unwrap();
    trainer.shutdown();

    // All tie-group replicas must remain bit-for-bit identical after
    // training (identical init + identical summed gradient + same Adam).
    let first = &params[members[0].0][members[0].1];
    for &(s, pi) in &members[1..] {
        let t = &params[s][pi];
        assert_eq!(first.shape, t.shape);
        let diff = first.max_abs_diff(t);
        assert!(diff == 0.0, "tied replicas diverged by {diff}");
    }
}

#[test]
fn bubble_fill_step_runs_and_losses_stay_sane() {
    if !have_artifacts("ee-tiny") {
        eprintln!("skipping: run `make artifacts`");
        return;
    }
    let man = Manifest::load_config(&artifacts_root(), "ee-tiny").unwrap();
    let mut ds = dataset_for(&man, 5);
    let mut o = opts(10);
    o.bubble_fill = 1;
    let mut trainer = PipelineTrainer::new(man, o).unwrap();
    let batches: Vec<TrainBatch> =
        (0..3).map(|_| ds.next_microbatch()).collect();
    let fills: Vec<TrainBatch> = (0..1).map(|_| ds.next_microbatch()).collect();
    let stats = trainer.train_step(&batches, &fills).unwrap();
    trainer.shutdown();
    // P=2, b/f=2 -> capacity floor(1/1.5) = 0: the planner must cap fills.
    assert_eq!(stats.fill_contributions, 0);
    assert!(stats.losses.iter().all(|l| l.is_finite() && *l > 0.0));
}

#[test]
fn training_reduces_loss_over_steps() {
    if !have_artifacts("ee-tiny") {
        eprintln!("skipping: run `make artifacts`");
        return;
    }
    let man = Manifest::load_config(&artifacts_root(), "ee-tiny").unwrap();
    let mut ds = dataset_for(&man, 1);
    let mut o = opts(30);
    o.lr = LrSchedule::cosine(3e-3, 3, 30);
    o.grad_clip = 1.0;
    let mut trainer = PipelineTrainer::new(man, o).unwrap();
    let mut first = None;
    let mut last = None;
    for _ in 0..30 {
        let batches: Vec<TrainBatch> =
            (0..2).map(|_| ds.next_microbatch()).collect();
        let stats = trainer.train_step(&batches, &[]).unwrap();
        let final_loss = *stats.losses.last().unwrap();
        if first.is_none() {
            first = Some(final_loss);
        }
        last = Some(final_loss);
    }
    trainer.shutdown();
    let (first, last) = (first.unwrap(), last.unwrap());
    assert!(
        last < first * 0.8,
        "loss did not decrease: {first} -> {last}"
    );
}
