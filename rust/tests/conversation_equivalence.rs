//! Integration: conversational KV reuse must be *invisible* in the
//! outputs. A multi-turn chat driven with end-of-turn snapshots (each
//! follow-up turn restores its conversation's stored history and
//! prefills only its own new text) must stream token-for-token and
//! exit-layer-for-exit-layer identical results to a cold replay of the
//! byte-identical prompts through a snapshot-free pool — on both
//! engines, across exit policies including the full-model baseline,
//! when the store budget evicts or rejects snapshots mid-conversation,
//! and with the device tier pinned on vs. host-only.
//!
//! End-of-turn snapshots carry generated (not just prompt) KV entries
//! plus deficit bookkeeping across turns, which is exactly the kind of
//! state that corrupts outputs silently; hence this suite.

use std::collections::BTreeMap;
use std::path::PathBuf;
use std::time::Duration;

use eellm::config::{LossWeightSchedule, LrSchedule};
use eellm::data::dataset::{Dataset, TrainBatch};
use eellm::data::synth::{
    conversation_traffic, ConvoSpec, ConvoTurn, Corpus, CorpusSpec,
};
use eellm::inference::{ExitPolicy, ModelState};
use eellm::runtime::artifacts::Manifest;
use eellm::serve::{
    ControlConfig, ConvoStats, EngineKind, EnginePool, Policy, PoolConfig,
    ServeEvent, ServeRequest,
};
use eellm::training::trainer::{PipelineTrainer, TrainerOptions};

/// One request's (token, exit layer) emissions, in stream order.
type Stream = Vec<(i32, usize)>;
/// Per-conversation, per-turn streams.
type Streams = Vec<Vec<Stream>>;
/// Recorded turns: (request id, stitched prompt, max_new) per round.
type Plan = Vec<Vec<(u64, String, usize)>>;

fn artifacts_root() -> PathBuf {
    PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts")
}

fn have_artifacts() -> bool {
    let ok = artifacts_root().join("ee-tiny").join("manifest.json").is_file();
    if !ok {
        eprintln!("skipping: run `make artifacts`");
    }
    ok
}

/// Train ee-tiny briefly so exit confidences are meaningful (an untrained
/// model has near-uniform logits and ties everywhere).
fn trained_state(man: &Manifest, steps: usize) -> ModelState {
    let corpus = Corpus::build(&CorpusSpec {
        seed: 7,
        n_entities: 8,
        target_bytes: 120_000,
    });
    let mut ds =
        Dataset::from_corpus(&corpus, man.model.seq, man.model.microbatch, 3);
    let mut trainer = PipelineTrainer::new(
        man.clone(),
        TrainerOptions {
            seed: 42,
            lr: LrSchedule::cosine(3e-3, 5, steps),
            grad_clip: 1.0,
            loss_weights: LossWeightSchedule::Constant,
            total_steps: steps,
            bubble_fill: 0,
            bf_ratio: 2.0,
        },
    )
    .unwrap();
    for _ in 0..steps {
        let batches: Vec<TrainBatch> =
            (0..2).map(|_| ds.next_microbatch()).collect();
        trainer.train_step(&batches, &[]).unwrap();
    }
    let params = trainer.params().unwrap();
    trainer.shutdown();
    ModelState { man: man.clone(), stage_params: params }
}

fn small_corpus() -> Corpus {
    Corpus::build(&CorpusSpec {
        seed: 7,
        n_entities: 8,
        target_bytes: 50_000,
    })
}

fn pool_cfg(
    engine: EngineKind,
    policy: ExitPolicy,
    positions: usize,
    device: usize,
) -> PoolConfig {
    PoolConfig {
        workers: 1,
        engine,
        policy,
        sched: Policy::Fifo,
        max_concurrent: 2,
        prefix_cache_positions: positions,
        device_tier_positions: device,
        convo_idle_ttl: Duration::from_secs(300),
        lane_fusion: false,
        lane_residency: true,
        control: ControlConfig::default(),
    }
}

/// Drive the conversations round-by-round with `with_conversation`
/// tagging, stitching each turn's prompt from the previous turns' actual
/// responses. Returns the recorded plan (for cold replay), the streamed
/// (token, exit layer) sequences per conversation turn, and the merged
/// conversation counters.
fn drive_warm(
    pool: &mut EnginePool,
    convos: &[Vec<ConvoTurn>],
    max_seq: usize,
) -> (Plan, Streams, ConvoStats) {
    let n = convos.len();
    let turns = convos[0].len();
    let mut history: Vec<String> = vec![String::new(); n];
    let mut plan: Plan = Vec::new();
    let mut streams: Streams = vec![Vec::new(); n];
    let mut agg = ConvoStats::default();
    for r in 0..turns {
        let mut round: Vec<(u64, String, usize)> = Vec::new();
        let mut reqs = Vec::new();
        for (c, track) in convos.iter().enumerate() {
            let t = &track[r];
            let prompt = format!("{}{}", history[c], t.user_text);
            assert!(
                prompt.len() + t.max_new + 4 < max_seq,
                "conversation outgrew max_seq; shrink the spec"
            );
            let id = (r * n + c) as u64;
            reqs.push(
                ServeRequest::new(id, prompt.as_str(), t.max_new)
                    .with_conversation(c as u64),
            );
            round.push((id, prompt, t.max_new));
        }
        let mut per: BTreeMap<u64, Stream> = BTreeMap::new();
        let out = pool
            .run_batch_streamed(reqs, |ev| {
                if let ServeEvent::Token { id, token, exit_layer, .. } = ev {
                    per.entry(*id).or_default().push((*token, *exit_layer));
                }
            })
            .unwrap();
        assert!(out.failures.is_empty(), "{:?}", out.failures);
        agg.merge(&out.metrics.convo);
        for (id, prompt, _) in &round {
            let rsp = out
                .responses
                .iter()
                .find(|x| x.id == *id)
                .expect("warm response");
            let c = (*id as usize) % n;
            history[c] = format!("{prompt}{}", rsp.output.text);
            streams[c].push(per.remove(id).unwrap_or_default());
        }
        plan.push(round);
    }
    (plan, streams, agg)
}

/// Replay the recorded plan with *untagged* requests: no conversation
/// registry, no restores, full prefill every turn.
fn drive_cold(
    pool: &mut EnginePool,
    plan: &Plan,
    n: usize,
) -> (Streams, ConvoStats) {
    let mut streams: Streams = vec![Vec::new(); n];
    let mut agg = ConvoStats::default();
    for round in plan {
        let reqs: Vec<ServeRequest> = round
            .iter()
            .map(|(id, p, m)| ServeRequest::new(*id, p.as_str(), *m))
            .collect();
        let mut per: BTreeMap<u64, Stream> = BTreeMap::new();
        let out = pool
            .run_batch_streamed(reqs, |ev| {
                if let ServeEvent::Token { id, token, exit_layer, .. } = ev {
                    per.entry(*id).or_default().push((*token, *exit_layer));
                }
            })
            .unwrap();
        assert!(out.failures.is_empty(), "{:?}", out.failures);
        agg.merge(&out.metrics.convo);
        for (id, _, _) in round {
            streams[(*id as usize) % n]
                .push(per.remove(id).unwrap_or_default());
        }
    }
    (streams, agg)
}

/// The acceptance grid: both engines x >= 3 exit policies (including
/// the tau = 1.0 full-model baseline). Every follow-up turn must restore
/// its conversation snapshot (no misses under an ample budget) and the
/// warm streams must equal the cold replay exactly.
#[test]
fn warm_conversation_equals_cold_replay_across_policies_and_engines() {
    if !have_artifacts() {
        return;
    }
    let man = Manifest::load_config(&artifacts_root(), "ee-tiny").unwrap();
    let state = trained_state(&man, 60);
    let corpus = small_corpus();
    let convos = conversation_traffic(
        &ConvoSpec {
            seed: 19,
            n_conversations: 3,
            turns: 3,
            n_system: 2,
            system_bytes: 48,
            tenants: vec![1.0],
            max_new: (2, 4),
            think_ms: (0, 1),
        },
        &corpus.facts,
    );
    let n = convos.len();
    let follow = (convos[0].len() - 1) * n;
    let max_seq = man.model.max_seq;
    let policies = [
        ExitPolicy::confidence(1.0),
        ExitPolicy::confidence(0.6),
        ExitPolicy::confidence(0.0),
    ];
    for &kind in &[EngineKind::Sequential, EngineKind::Pipelined] {
        for policy in &policies {
            let mut warm = EnginePool::new(
                state.clone(),
                pool_cfg(kind, policy.clone(), 16 * max_seq, 0),
            );
            let (plan, warm_streams, ws) =
                drive_warm(&mut warm, &convos, max_seq);
            warm.shutdown().unwrap();
            assert_eq!(
                ws.first_turns as usize, n,
                "{kind:?} {policy:?}: opening turns miscounted: {ws:?}"
            );
            assert_eq!(
                ws.restore_hits as usize, follow,
                "{kind:?} {policy:?}: a follow-up turn missed its \
                 snapshot: {ws:?}"
            );
            assert_eq!(ws.restore_misses, 0, "{kind:?} {policy:?}: {ws:?}");
            assert!(
                ws.saved_positions > 0,
                "{kind:?} {policy:?}: restores saved nothing: {ws:?}"
            );
            assert_eq!(
                ws.snapshot_failures, 0,
                "{kind:?} {policy:?}: {ws:?}"
            );

            let mut cold = EnginePool::new(
                state.clone(),
                pool_cfg(kind, policy.clone(), 0, 0),
            );
            let (cold_streams, cs) = drive_cold(&mut cold, &plan, n);
            cold.shutdown().unwrap();
            assert_eq!(
                cs.turns, 0,
                "untagged replay recorded conversation turns"
            );
            assert_eq!(
                warm_streams, cold_streams,
                "{kind:?} {policy:?}: conversation snapshots changed the \
                 streamed tokens or exit layers"
            );
        }
    }
}

/// A budget that fits one opening-turn snapshot but never two — and
/// rejects the deeper turns outright — churns the store on every round:
/// one conversation's history is evicted by the other's insert, so its
/// next turn misses and must fall back to full prefill. Streams must
/// still equal the cold replay. Untrained weights + threshold 0.0 maximise
/// the recompute deficit the snapshots carry.
#[test]
fn eviction_mid_conversation_keeps_streams_identical() {
    if !have_artifacts() {
        return;
    }
    let man = Manifest::load_config(&artifacts_root(), "ee-tiny").unwrap();
    let state = ModelState::init(man.clone(), 9);
    let corpus = small_corpus();
    // n_system = 2 gives the two conversations *disjoint* system
    // prompts: an evicted history cannot be partially served by the
    // other conversation's entry, so the miss is a real full prefill.
    let convos = conversation_traffic(
        &ConvoSpec {
            seed: 23,
            n_conversations: 2,
            turns: 3,
            n_system: 2,
            system_bytes: 48,
            tenants: vec![1.0],
            max_new: (2, 4),
            think_ms: (0, 1),
        },
        &corpus.facts,
    );
    let n = convos.len();
    let follow = (convos[0].len() - 1) * n;

    let mut warm = EnginePool::new(
        state.clone(),
        pool_cfg(EngineKind::Sequential, ExitPolicy::confidence(0.0), 128, 0),
    );
    let (plan, warm_streams, ws) =
        drive_warm(&mut warm, &convos, man.model.max_seq);
    let store_stats = warm.prefix_stores()[0].stats();
    warm.shutdown().unwrap();
    assert_eq!(
        (ws.restore_hits + ws.restore_misses) as usize,
        follow,
        "{ws:?}"
    );
    assert!(
        ws.restore_misses > 0,
        "the tiny budget never forced a restore miss: {ws:?}"
    );
    assert!(
        ws.restore_hits > 0,
        "the surviving entry was never restored: {ws:?}"
    );
    assert!(
        store_stats.evictions > 0 || ws.snapshots_rejected > 0,
        "the budget never churned the store: {store_stats:?} {ws:?}"
    );

    let mut cold = EnginePool::new(
        state,
        pool_cfg(EngineKind::Sequential, ExitPolicy::confidence(0.0), 0, 0),
    );
    let (cold_streams, _) = drive_cold(&mut cold, &plan, n);
    cold.shutdown().unwrap();
    assert_eq!(
        warm_streams, cold_streams,
        "mid-conversation eviction changed the streamed tokens or exit \
         layers"
    );
}

/// Device-tier parity: the same conversations through a host-only store
/// and a store with a pinned device tier must restore identically —
/// same streams, same restore hits, same positions saved.
#[test]
fn device_tier_is_invisible_to_conversation_streams() {
    if !have_artifacts() {
        return;
    }
    let man = Manifest::load_config(&artifacts_root(), "ee-tiny").unwrap();
    let state = ModelState::init(man.clone(), 9);
    let corpus = small_corpus();
    let convos = conversation_traffic(
        &ConvoSpec {
            seed: 31,
            n_conversations: 3,
            turns: 3,
            n_system: 2,
            system_bytes: 48,
            tenants: vec![1.0],
            max_new: (2, 4),
            think_ms: (0, 1),
        },
        &corpus.facts,
    );
    let n = convos.len();
    let follow = (convos[0].len() - 1) * n;
    let max_seq = man.model.max_seq;

    let mut runs: Vec<(Streams, u64)> = Vec::new();
    for &device in &[0usize, 4 * max_seq] {
        let mut pool = EnginePool::new(
            state.clone(),
            pool_cfg(
                EngineKind::Sequential,
                ExitPolicy::confidence(0.6),
                16 * max_seq,
                device,
            ),
        );
        let (_, streams, ws) = drive_warm(&mut pool, &convos, max_seq);
        let tier = pool.prefix_stores()[0].tier_stats();
        pool.shutdown().unwrap();
        assert_eq!(ws.restore_misses, 0, "device {device}: {ws:?}");
        assert_eq!(
            ws.restore_hits as usize, follow,
            "device {device}: {ws:?}"
        );
        assert!(
            tier.lookups() > 0,
            "device {device}: the tiered store was never consulted"
        );
        if device == 0 {
            assert_eq!(tier.device_hits, 0, "{tier:?}");
        }
        runs.push((streams, ws.saved_positions));
    }
    assert_eq!(
        runs[0], runs[1],
        "the device tier changed conversation streams or savings"
    );
}
