//! Integration: shared-prefix KV-cache reuse must be *invisible* in the
//! outputs. For a grid of exit thresholds and prompt-overlap patterns,
//! decoding with the prefix cache enabled must produce token-for-token
//! and exit-layer-for-exit-layer identical results to decoding without
//! it — including when entries are evicted mid-workload and sessions
//! fall back to full prefill, and under the serving pool's continuous
//! batching where live sessions pin the prefixes new admissions look up.
//!
//! Cache reuse is exactly the kind of optimisation that corrupts outputs
//! silently (stale KV entries change logits, not error codes), which is
//! why the feature ships inside this suite.

use std::path::PathBuf;

use eellm::config::{LossWeightSchedule, LrSchedule};
use eellm::data::dataset::{Dataset, TrainBatch};
use eellm::data::synth::{
    shared_prefix_prompts, Corpus, CorpusSpec, SharedPrefixSpec,
};
use eellm::inference::{
    DecodeSession, ExitPolicy, ModelState, PrefixCacheStore,
    SequentialEngine, StepEvent,
};
use eellm::runtime::artifacts::Manifest;
use eellm::serve::{
    ControlConfig, EngineKind, EnginePool, Policy, PoolConfig, ServeEvent,
    ServeRequest,
};
use eellm::training::trainer::{PipelineTrainer, TrainerOptions};

fn artifacts_root() -> PathBuf {
    PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts")
}

fn have_artifacts() -> bool {
    let ok = artifacts_root().join("ee-tiny").join("manifest.json").is_file();
    if !ok {
        eprintln!("skipping: run `make artifacts`");
    }
    ok
}

/// Train ee-tiny briefly so exit confidences are meaningful (an untrained
/// model has near-uniform logits and ties everywhere).
fn trained_state(man: &Manifest, steps: usize) -> ModelState {
    let corpus = Corpus::build(&CorpusSpec {
        seed: 7,
        n_entities: 8,
        target_bytes: 120_000,
    });
    let mut ds =
        Dataset::from_corpus(&corpus, man.model.seq, man.model.microbatch, 3);
    let mut trainer = PipelineTrainer::new(
        man.clone(),
        TrainerOptions {
            seed: 42,
            lr: LrSchedule::cosine(3e-3, 5, steps),
            grad_clip: 1.0,
            loss_weights: LossWeightSchedule::Constant,
            total_steps: steps,
            bubble_fill: 0,
            bf_ratio: 2.0,
        },
    )
    .unwrap();
    for _ in 0..steps {
        let batches: Vec<TrainBatch> =
            (0..2).map(|_| ds.next_microbatch()).collect();
        trainer.train_step(&batches, &[]).unwrap();
    }
    let params = trainer.params().unwrap();
    trainer.shutdown();
    ModelState { man: man.clone(), stage_params: params }
}

/// Drain one session, collecting (token, exit layer) per emission. With a
/// store, mirrors the pool's admission flow: cached prefill, then insert
/// the full-prompt snapshot unless an entry already covers it.
fn run_session(
    eng: &mut SequentialEngine,
    prompt: &str,
    max_new: usize,
    store: Option<&PrefixCacheStore>,
) -> Vec<(i32, usize)> {
    let mut s = DecodeSession::new_text(eng, prompt, max_new).unwrap();
    match store {
        Some(st) => {
            let cached = s.prefill_with_cache(eng, st).unwrap();
            if !s.is_done() && cached.cached_tokens < s.prompt_len() {
                st.insert(s.prefix_snapshot(eng).unwrap());
            }
        }
        None => s.prefill(eng).unwrap(),
    }
    let mut out = Vec::new();
    while !s.is_done() {
        if let StepEvent::Token { token, exit_layer, .. } =
            s.step(eng).unwrap()
        {
            out.push((token, exit_layer));
        }
    }
    out
}

/// The acceptance grid: >= 3 exit thresholds x prompt-overlap patterns.
/// One store per pattern is shared across *all* thresholds — prefill
/// snapshots are threshold-independent (prefill never takes exits), so a
/// snapshot inserted at tau=1.0 must serve a tau=0.2 session unchanged.
#[test]
fn cache_on_equals_cache_off_across_thresholds_and_overlap() {
    if !have_artifacts() {
        return;
    }
    let man = Manifest::load_config(&artifacts_root(), "ee-tiny").unwrap();
    let state = trained_state(&man, 60);

    let corpus = Corpus::build(&CorpusSpec {
        seed: 7,
        n_entities: 8,
        target_bytes: 50_000,
    });
    let shared = shared_prefix_prompts(
        &SharedPrefixSpec {
            seed: 5,
            n_groups: 2,
            requests_per_group: 3,
            prefix_bytes: man.model.max_seq / 2,
        },
        &corpus.facts,
    );
    let nested = vec![
        "abc: a b c ".to_string(),
        "abc: a b c d e ".to_string(),
        "abc: a b c d e f g ".to_string(),
    ];
    let disjoint = vec!["3+4=".to_string(), "count: 1 2 3 ".to_string()];
    let patterns: Vec<(&str, Vec<String>, bool)> = vec![
        ("shared-system-prompt", shared, true),
        ("nested-prefixes", nested, true),
        ("disjoint", disjoint, false),
    ];

    let thresholds = [1.0f32, 0.6, 0.2];
    let stores: Vec<PrefixCacheStore> = patterns
        .iter()
        .map(|_| PrefixCacheStore::new(64 * man.model.max_seq))
        .collect();
    for &tau in &thresholds {
        let mut eng = SequentialEngine::new(state.clone(), ExitPolicy::confidence(tau)).unwrap();
        for ((name, prompts, _), store) in patterns.iter().zip(&stores) {
            for p in prompts {
                let baseline = run_session(&mut eng, p, 16, None);
                let cached = run_session(&mut eng, p, 16, Some(store));
                assert_eq!(
                    baseline, cached,
                    "pattern {name}, tau {tau}, prompt {p:?}: cached \
                     decode diverged (tokens or exit layers)"
                );
            }
        }
    }
    for ((name, _, expect_hits), store) in patterns.iter().zip(&stores) {
        let st = store.stats();
        assert!(
            st.lookups() > 0,
            "pattern {name}: the store was never consulted"
        );
        if *expect_hits {
            assert!(st.hits > 0, "pattern {name}: no prefix hits: {st:?}");
            assert!(
                st.saved_positions > 0,
                "pattern {name}: hits saved no prefill positions: {st:?}"
            );
        }
        assert!(
            store.used_positions() <= store.max_positions(),
            "pattern {name}: budget exceeded"
        );
        assert_eq!(
            store.pinned_entries(),
            0,
            "pattern {name}: sessions leaked pins"
        );
    }
}

/// A budget that only fits one snapshot forces eviction every time the
/// workload alternates groups; sessions that resume after their prefix
/// was evicted must fall back to full prefill with identical outputs.
#[test]
fn eviction_mid_workload_keeps_outputs_identical() {
    if !have_artifacts() {
        return;
    }
    let man = Manifest::load_config(&artifacts_root(), "ee-tiny").unwrap();
    // Untrained weights + threshold 0.0: every token exits at the first
    // early exit, so restores interact with the recompute deficit
    // machinery as hard as possible.
    let state = ModelState::init(man.clone(), 9);
    let corpus = Corpus::build(&CorpusSpec {
        seed: 7,
        n_entities: 8,
        target_bytes: 50_000,
    });
    // Two groups, interleaved arrival: a1 b1 a2 b2 a3 b3.
    let prompts = shared_prefix_prompts(
        &SharedPrefixSpec {
            seed: 13,
            n_groups: 2,
            requests_per_group: 3,
            prefix_bytes: 80,
        },
        &corpus.facts,
    );
    let longest = prompts.iter().map(|p| p.len()).max().unwrap() + 1;
    // Room for one snapshot, never two: every group switch evicts.
    let store = PrefixCacheStore::new(longest + 8);

    let mut eng = SequentialEngine::new(state, ExitPolicy::confidence(0.0)).unwrap();
    for p in &prompts {
        let baseline = run_session(&mut eng, p, 12, None);
        let cached = run_session(&mut eng, p, 12, Some(&store));
        assert_eq!(
            baseline, cached,
            "prompt {p:?} diverged after mid-workload eviction"
        );
    }
    let st = store.stats();
    assert!(st.evictions > 0, "budget never forced an eviction: {st:?}");
    assert!(st.hits > 0, "no hits despite shared group prefixes: {st:?}");
    assert!(store.used_positions() <= store.max_positions());
}

/// Pool-level equivalence: the same shared-prefix batch through
/// continuous-batching workers with the cache on vs. off must stream
/// identical (token, exit layer) sequences per request, and the cached
/// run must report nonzero hits and prefill savings in its metrics.
#[test]
fn pooled_prefix_cache_matches_disabled_and_saves_prefill() {
    if !have_artifacts() {
        return;
    }
    let man = Manifest::load_config(&artifacts_root(), "ee-tiny").unwrap();
    let state = ModelState::init(man.clone(), 9);
    let corpus = Corpus::build(&CorpusSpec {
        seed: 7,
        n_entities: 8,
        target_bytes: 50_000,
    });
    let prompts = shared_prefix_prompts(
        &SharedPrefixSpec {
            seed: 3,
            n_groups: 2,
            requests_per_group: 3,
            prefix_bytes: man.model.max_seq / 2,
        },
        &corpus.facts,
    );

    for &tau in &[1.0f32, 0.0] {
        let mut streams: Vec<Vec<Vec<(i32, usize)>>> = Vec::new();
        let mut saved = Vec::new();
        for &budget in &[0usize, 32 * man.model.max_seq] {
            let mut pool = EnginePool::new(
                state.clone(),
                PoolConfig {
                    workers: 1,
                    engine: EngineKind::Sequential,
                    policy: ExitPolicy::confidence(tau),
                    sched: Policy::Fifo,
                    max_concurrent: 2,
                    prefix_cache_positions: budget,
                    device_tier_positions: 0,
                    convo_idle_ttl: std::time::Duration::from_secs(300),
                    lane_fusion: false,
                    lane_residency: true,
                    control: ControlConfig::default(),
                },
            );
            let reqs: Vec<ServeRequest> = prompts
                .iter()
                .enumerate()
                .map(|(i, p)| ServeRequest::new(i as u64, p.as_str(), 8))
                .collect();
            let mut per_req: Vec<Vec<(i32, usize)>> =
                vec![Vec::new(); reqs.len()];
            let out = pool
                .run_batch_streamed(reqs, |e| {
                    if let ServeEvent::Token {
                        id, token, exit_layer, ..
                    } = e
                    {
                        per_req[*id as usize].push((*token, *exit_layer));
                    }
                })
                .unwrap();
            pool.shutdown().unwrap();
            assert!(out.failures.is_empty(), "{:?}", out.failures);
            if budget == 0 {
                assert_eq!(out.metrics.prefix.lookups(), 0);
            } else {
                assert!(out.metrics.prefix.hits > 0, "tau {tau}: no hits");
                assert!(
                    out.metrics.prefill_positions_saved() > 0,
                    "tau {tau}: nothing saved"
                );
            }
            saved.push(out.metrics.prefill_positions_saved());
            streams.push(per_req);
        }
        assert_eq!(
            streams[0], streams[1],
            "tau {tau}: prefix cache changed streamed tokens/exit layers \
             (saved {saved:?})"
        );
    }
}

/// Concurrency: admissions whose prefix is pinned by live sessions must
/// neither deadlock nor double-release the snapshot. One worker
/// interleaves up to `max_concurrent` sessions over one shared prefix,
/// repeatedly; afterwards every pin must be released exactly once
/// (a double-release would wrap the pin counter and show up as a
/// permanently-pinned entry).
#[test]
fn pinned_prefix_admission_stress_no_deadlock_or_double_release() {
    if !have_artifacts() {
        return;
    }
    let man = Manifest::load_config(&artifacts_root(), "ee-tiny").unwrap();
    let state = ModelState::init(man.clone(), 4);
    let corpus = Corpus::build(&CorpusSpec {
        seed: 7,
        n_entities: 8,
        target_bytes: 50_000,
    });
    let prompts = shared_prefix_prompts(
        &SharedPrefixSpec {
            seed: 21,
            n_groups: 1,
            requests_per_group: 8,
            prefix_bytes: man.model.max_seq / 2,
        },
        &corpus.facts,
    );
    // Varying budgets finish sessions at different times, churning the
    // pin set while later admissions look the prefix up.
    let budgets: Vec<usize> = (0..prompts.len()).map(|i| 1 + i % 5).collect();

    for &tau in &[1.0f32, 0.0] {
        let mut eng = SequentialEngine::new(state.clone(), ExitPolicy::confidence(tau)).unwrap();
        let serial: Vec<Vec<(i32, usize)>> = prompts
            .iter()
            .zip(&budgets)
            .map(|(p, &b)| run_session(&mut eng, p, b, None))
            .collect();
        for max_concurrent in [2usize, 3, 4] {
            let mut pool = EnginePool::new(
                state.clone(),
                PoolConfig {
                    workers: 1,
                    engine: EngineKind::Sequential,
                    policy: ExitPolicy::confidence(tau),
                    sched: Policy::Fifo,
                    max_concurrent,
                    prefix_cache_positions: 16 * man.model.max_seq,
                    device_tier_positions: 0,
                    convo_idle_ttl: std::time::Duration::from_secs(300),
                    lane_fusion: false,
                    lane_residency: true,
                    control: ControlConfig::default(),
                },
            );
            let stores: Vec<_> = pool.prefix_stores().to_vec();
            assert_eq!(stores.len(), 1);
            for round in 0..2 {
                let reqs: Vec<ServeRequest> = prompts
                    .iter()
                    .zip(&budgets)
                    .enumerate()
                    .map(|(i, (p, &b))| {
                        ServeRequest::new(i as u64, p.as_str(), b)
                    })
                    .collect();
                let out = pool.run_batch(reqs).unwrap();
                assert!(out.failures.is_empty(), "{:?}", out.failures);
                assert_eq!(out.responses.len(), prompts.len());
                for (i, r) in out.responses.iter().enumerate() {
                    let want: Vec<i32> =
                        serial[i].iter().map(|&(t, _)| t).collect();
                    assert_eq!(
                        r.output.tokens, want,
                        "request {i} diverged (tau {tau}, \
                         concurrent {max_concurrent}, round {round})"
                    );
                }
                // The second round runs against a warm store.
                if round > 0 {
                    assert!(out.metrics.prefix.hits > 0);
                }
            }
            pool.shutdown().unwrap();
            // Workers have exited: every session pin must be released.
            assert_eq!(
                stores[0].pinned_entries(),
                0,
                "leaked or double-released pins (tau {tau}, \
                 concurrent {max_concurrent})"
            );
            assert!(
                stores[0].used_positions() <= stores[0].max_positions()
            );
        }
    }
}
