//! Smoke: load + compile + execute every ee-tiny artifact on PJRT CPU.

use std::path::PathBuf;

use eellm::runtime::artifacts::Manifest;
use eellm::runtime::client::StageRuntime;
use eellm::runtime::params;
use eellm::runtime::tensor::{HostTensor, IntTensor};

fn artifacts_root() -> PathBuf {
    PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts")
}

#[test]
fn compile_and_run_every_ee_tiny_executable() {
    let root = artifacts_root();
    if !root.join("ee-tiny").is_dir() {
        eprintln!("skipping: run `make artifacts`");
        return;
    }
    let man = Manifest::load_config(&root, "ee-tiny").unwrap();
    let m = &man.model;
    for st in &man.stages {
        let mut rt = StageRuntime::cpu().unwrap();
        rt.load_stage_training(&man, st).unwrap();
        rt.load_stage_inference(&man, st).unwrap();

        let params = params::init_stage(1, &man, st.index);
        let plits: Vec<xla::Literal> =
            params.iter().map(|p| p.to_literal().unwrap()).collect();

        // fwd
        let input: xla::Literal = if st.index == 0 {
            IntTensor::new(
                vec![m.microbatch, m.seq],
                vec![65; m.microbatch * m.seq],
            )
            .to_literal()
            .unwrap()
        } else {
            HostTensor::zeros(&[m.microbatch, m.seq, m.hidden])
                .to_literal()
                .unwrap()
        };
        let mut args: Vec<&xla::Literal> = plits.iter().collect();
        args.push(&input);
        let out = rt.get("fwd").unwrap().run(&args).unwrap();
        let x = HostTensor::from_literal(&out[0]).unwrap();
        assert_eq!(x.shape, vec![m.microbatch, m.seq, m.hidden]);
        assert!(x.data.iter().all(|v| v.is_finite()));

        // decode w1
        let cache = HostTensor::zeros(&st.cache_shape).to_literal().unwrap();
        let din: xla::Literal = if st.index == 0 {
            IntTensor::new(vec![1], vec![66]).to_literal().unwrap()
        } else {
            HostTensor::zeros(&[1, m.hidden]).to_literal().unwrap()
        };
        let pos = IntTensor::scalar(0).to_literal().unwrap();
        let mut args: Vec<&xla::Literal> = plits.iter().collect();
        args.push(&din);
        args.push(&cache);
        args.push(&pos);
        let out = rt.get("decode_w1").unwrap().run(&args).unwrap();
        assert_eq!(out.len(), 2);
        let x = HostTensor::from_literal(&out[0]).unwrap();
        assert_eq!(x.shape, vec![1, m.hidden]);

        // heads
        for e in &st.exits {
            let h = HostTensor::zeros(&[m.hidden]).to_literal().unwrap();
            let hp: Vec<&xla::Literal> =
                e.head_param_idx.iter().map(|&i| &plits[i]).collect();
            let mut args = hp;
            args.push(&h);
            let out =
                rt.get(&format!("head{}", e.layer)).unwrap().run(&args).unwrap();
            let logits = HostTensor::from_literal(&out[0]).unwrap();
            assert_eq!(logits.shape, vec![m.vocab]);
        }
    }
}
