//! Integration: the `ExitPolicy` redesign must be invisible where it
//! claims compatibility and meaningful where it adds power.
//!
//! - `Confidence{t}` is the old scalar-threshold path bit-for-bit: both
//!   engines produce identical (token, exit-layer) streams for the same
//!   prompt across thresholds {0.6, 0.9, 1.0}, and 1.0 is the
//!   full-model baseline (every token from the final exit, no
//!   forced-full accounting) exactly as the pre-policy code defined it.
//! - `Never` always runs full depth, on both engines, whatever the
//!   model.
//! - `PerLayer` with one uniform threshold on every exit layer decodes
//!   identically to `Confidence` with that threshold.
//! - Per-request policy overrides through the serving pool reproduce
//!   the serial engine's streams (the pool's policy swap is sound), and
//!   the `with_threshold` sugar is indistinguishable from
//!   `with_policy(Confidence)`.

use std::path::PathBuf;

use eellm::config::{LossWeightSchedule, LrSchedule};
use eellm::data::dataset::{Dataset, TrainBatch};
use eellm::data::synth::{Corpus, CorpusSpec};
use eellm::inference::{
    DecodeBackend, DecodeSession, ExitPolicy, ModelState, PipelinedEngine,
    SequentialEngine, StepEvent,
};
use eellm::runtime::artifacts::Manifest;
use eellm::serve::{
    ControlConfig, EngineKind, EnginePool, Policy, PoolConfig,
    ServeRequest,
};
use eellm::training::trainer::{PipelineTrainer, TrainerOptions};

fn artifacts_root() -> PathBuf {
    PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts")
}

fn have_artifacts() -> bool {
    let ok = artifacts_root().join("ee-tiny").join("manifest.json").is_file();
    if !ok {
        eprintln!("skipping: run `make artifacts`");
    }
    ok
}

/// Train ee-tiny briefly so confidences are meaningful (same recipe as
/// the sibling equivalence suites).
fn trained_state(man: &Manifest, steps: usize) -> ModelState {
    let corpus = Corpus::build(&CorpusSpec {
        seed: 7,
        n_entities: 8,
        target_bytes: 120_000,
    });
    let mut ds =
        Dataset::from_corpus(&corpus, man.model.seq, man.model.microbatch, 3);
    let mut trainer = PipelineTrainer::new(
        man.clone(),
        TrainerOptions {
            seed: 42,
            lr: LrSchedule::cosine(3e-3, 5, steps),
            grad_clip: 1.0,
            loss_weights: LossWeightSchedule::Constant,
            total_steps: steps,
            bubble_fill: 0,
            bf_ratio: 2.0,
        },
    )
    .unwrap();
    for _ in 0..steps {
        let batches: Vec<TrainBatch> =
            (0..2).map(|_| ds.next_microbatch()).collect();
        trainer.train_step(&batches, &[]).unwrap();
    }
    let params = trainer.params().unwrap();
    trainer.shutdown();
    ModelState { man: man.clone(), stage_params: params }
}

/// Drain one session over any backend, collecting the per-token
/// (token, exit layer) stream — the quantity every equivalence claim in
/// this suite is about.
fn stream(
    backend: &mut dyn DecodeBackend,
    prompt: &str,
    max_new: usize,
) -> Vec<(i32, usize)> {
    let mut s = DecodeSession::new_text(backend, prompt, max_new).unwrap();
    s.prefill(backend).unwrap();
    let mut out = Vec::new();
    while !s.is_done() {
        if let StepEvent::Token { token, exit_layer, .. } =
            s.step(backend).unwrap()
        {
            out.push((token, exit_layer));
        }
    }
    out
}

const PROMPTS: [&str; 4] = [
    "the capital of ",
    "question: what is the ",
    "count: 3 4 5 ",
    "abc: a b c d ",
];

/// The acceptance grid: `Confidence{t}` for t in {0.6, 0.9, 1.0}
/// produces identical (token, exit-layer) streams on both engines, and
/// t = 1.0 is the full-model baseline on both.
#[test]
fn confidence_streams_match_across_engines_and_thresholds() {
    if !have_artifacts() {
        return;
    }
    let man = Manifest::load_config(&artifacts_root(), "ee-tiny").unwrap();
    let state = trained_state(&man, 60);
    let n_layers = man.model.n_layers;

    let mut pipe =
        PipelinedEngine::new(state.clone(), ExitPolicy::confidence(1.0))
            .unwrap();
    // {0.6, 0.9, 1.0} is the acceptance grid; 0.2 additionally fires
    // real early exits on this briefly-trained model (confidences top
    // out near ~0.23 — see the Appendix B.1 suite).
    for &tau in &[0.2f32, 0.6, 0.9, 1.0] {
        let mut seq =
            SequentialEngine::new(state.clone(), ExitPolicy::confidence(tau))
                .unwrap();
        pipe.set_policy(ExitPolicy::confidence(tau));
        for p in &PROMPTS {
            let a = stream(&mut seq, p, 16);
            let b = stream(&mut pipe, p, 16);
            assert!(!a.is_empty(), "tau {tau}, prompt {p:?}: empty stream");
            assert_eq!(
                a, b,
                "tau {tau}, prompt {p:?}: engines diverged under \
                 Confidence (tokens or exit layers)"
            );
            if tau >= 1.0 {
                // The full-model baseline: every token from the final
                // exit, exactly like the old threshold-1.0 path.
                assert!(
                    a.iter().all(|&(_, l)| l == n_layers),
                    "tau 1.0 emitted an early exit: {a:?}"
                );
            }
        }
    }
    pipe.shutdown();
}

/// `Never` always runs full depth on both engines — and on the
/// sequential engine it skips the forced-full accounting exactly like
/// the old threshold-1.0 spelling (which it must equal token-for-token).
#[test]
fn never_always_runs_full_depth() {
    if !have_artifacts() {
        return;
    }
    let man = Manifest::load_config(&artifacts_root(), "ee-tiny").unwrap();
    let n_layers = man.model.n_layers;
    // Untrained weights make every exit confident-ish and tie-prone —
    // the hardest setting for a "never exit" claim.
    for seed in [3u64, 9, 17] {
        let state = ModelState::init(man.clone(), seed);
        let mut seq =
            SequentialEngine::new(state.clone(), ExitPolicy::Never).unwrap();
        let mut base = SequentialEngine::new(
            state.clone(),
            ExitPolicy::confidence(1.0),
        )
        .unwrap();
        let mut pipe =
            PipelinedEngine::new(state, ExitPolicy::Never).unwrap();
        for p in &PROMPTS {
            let a = stream(&mut seq, p, 12);
            assert!(
                a.iter().all(|&(_, l)| l == n_layers),
                "seed {seed}, prompt {p:?}: Never exited early: {a:?}"
            );
            assert_eq!(
                a,
                stream(&mut base, p, 12),
                "seed {seed}, prompt {p:?}: Never != Confidence{{1.0}}"
            );
            let b = stream(&mut pipe, p, 12);
            assert_eq!(a, b, "seed {seed}, prompt {p:?}: engines diverged");
        }
        let out = seq.generate_text("hello world", 12).unwrap();
        assert_eq!(
            out.stats.forced_full, 0,
            "Never must skip forced-full accounting"
        );
        pipe.shutdown();
    }
}

/// Property: `PerLayer` with a uniform threshold on every entry-exit
/// layer decodes identically to `Confidence` with that threshold — over
/// a grid of thresholds, model seeds, and prompts, on both engines.
#[test]
fn uniform_per_layer_equals_confidence() {
    if !have_artifacts() {
        return;
    }
    let man = Manifest::load_config(&artifacts_root(), "ee-tiny").unwrap();
    // Every early-exit layer the engines can fire at (entry exits).
    let exit_layers: Vec<usize> = {
        let state = ModelState::init(man.clone(), 1);
        let mut layers = Vec::new();
        for s in 0..state.man.stages.len() {
            layers.extend(state.entry_exits(s).iter().map(|e| e.layer));
        }
        layers
    };
    assert!(!exit_layers.is_empty());

    for seed in [5u64, 11] {
        let state = ModelState::init(man.clone(), seed);
        // One pipelined engine per seed; policies swap between sessions
        // (each session captures the policy set when it opens).
        let mut pipe =
            PipelinedEngine::new(state.clone(), ExitPolicy::Never).unwrap();
        for &tau in &[0.0f32, 0.3, 0.7, 1.0] {
            let uniform = ExitPolicy::PerLayer {
                thresholds: exit_layers.iter().map(|&l| (l, tau)).collect(),
            };
            let mut a =
                SequentialEngine::new(state.clone(), uniform.clone())
                    .unwrap();
            let mut b = SequentialEngine::new(
                state.clone(),
                ExitPolicy::confidence(tau),
            )
            .unwrap();
            for p in &PROMPTS {
                let sa = stream(&mut a, p, 10);
                assert_eq!(
                    sa,
                    stream(&mut b, p, 10),
                    "seed {seed}, tau {tau}, prompt {p:?}: sequential \
                     uniform PerLayer != Confidence"
                );
                // Each pipelined session decodes under the policy set
                // at its open: swap, run PerLayer, swap, run Confidence.
                pipe.set_policy(uniform.clone());
                let qa = stream(&mut pipe, p, 10);
                pipe.set_policy(ExitPolicy::confidence(tau));
                assert_eq!(
                    qa,
                    stream(&mut pipe, p, 10),
                    "seed {seed}, tau {tau}, prompt {p:?}: pipelined \
                     uniform PerLayer != Confidence"
                );
                // No cross-engine assertion here: at aggressive
                // thresholds the sequential engine's forced full-model
                // passes legitimately diverge from the pipelined
                // engine's in-band back-fill (see the Appendix B.1
                // suite for the cross-engine claims at the thresholds
                // where they hold).
            }
        }
        pipe.shutdown();
    }
}

/// Per-request policies through the serving pool: a batch mixing
/// `with_policy(Confidence)` and the `with_threshold` sugar must
/// reproduce the serial per-policy streams byte-for-byte, proving the
/// pool's engine-resident policy swap never leaks across sessions.
#[test]
fn pooled_per_request_policies_match_serial() {
    if !have_artifacts() {
        return;
    }
    let man = Manifest::load_config(&artifacts_root(), "ee-tiny").unwrap();
    let state = trained_state(&man, 60);

    // Serial baselines, one engine per distinct policy.
    let taus = [0.6f32, 1.0, 0.9, 0.6];
    let mut serial: Vec<Vec<i32>> = Vec::new();
    for (p, &tau) in PROMPTS.iter().zip(&taus) {
        let mut eng =
            SequentialEngine::new(state.clone(), ExitPolicy::confidence(tau))
                .unwrap();
        serial.push(stream(&mut eng, p, 12).iter().map(|&(t, _)| t).collect());
    }

    let mut pool = EnginePool::new(
        state,
        PoolConfig {
            workers: 1,
            engine: EngineKind::Sequential,
            // A pool default none of the requests use: any leak of the
            // default into a session shows up as a diverged stream.
            policy: ExitPolicy::confidence(0.2),
            sched: Policy::Fifo,
            max_concurrent: 2,
            prefix_cache_positions: 0,
            device_tier_positions: 0,
            convo_idle_ttl: std::time::Duration::from_secs(300),
            lane_fusion: false,
            lane_residency: true,
            control: ControlConfig::default(),
        },
    );
    let reqs: Vec<ServeRequest> = PROMPTS
        .iter()
        .zip(&taus)
        .enumerate()
        .map(|(i, (p, &tau))| {
            let r = ServeRequest::new(i as u64, *p, 12);
            if i % 2 == 0 {
                r.with_policy(ExitPolicy::confidence(tau))
            } else {
                r.with_threshold(tau) // the sugar spelling
            }
        })
        .collect();
    let out = pool.run_batch(reqs).unwrap();
    pool.shutdown().unwrap();
    assert!(out.failures.is_empty(), "{:?}", out.failures);
    assert_eq!(out.responses.len(), PROMPTS.len());
    for (i, r) in out.responses.iter().enumerate() {
        assert_eq!(
            r.output.tokens, serial[i],
            "request {i} (tau {}) diverged under pooled per-request \
             policies",
            taus[i]
        );
    }
}

/// Degenerate alternative policies collapse to known baselines: an
/// unsatisfiable margin bound decodes exactly like `Never`, and a
/// trivially-satisfied entropy bound exactly like `Confidence{0.0}`
/// (every token exits at the first eligible exit).
#[test]
fn margin_and_entropy_extremes_match_baselines() {
    if !have_artifacts() {
        return;
    }
    let man = Manifest::load_config(&artifacts_root(), "ee-tiny").unwrap();
    let state = ModelState::init(man, 9);

    let mut never =
        SequentialEngine::new(state.clone(), ExitPolicy::Never).unwrap();
    let mut margin_never = SequentialEngine::new(
        state.clone(),
        ExitPolicy::TopTwoMargin { delta: 2.0 },
    )
    .unwrap();
    let mut always =
        SequentialEngine::new(state.clone(), ExitPolicy::confidence(0.0))
            .unwrap();
    let mut entropy_always = SequentialEngine::new(
        state,
        ExitPolicy::Entropy { max_nats: f32::MAX },
    )
    .unwrap();
    for p in &PROMPTS {
        assert_eq!(
            stream(&mut never, p, 10),
            stream(&mut margin_never, p, 10),
            "prompt {p:?}: unsatisfiable margin != Never"
        );
        assert_eq!(
            stream(&mut always, p, 10),
            stream(&mut entropy_always, p, 10),
            "prompt {p:?}: trivial entropy bound != confidence 0.0"
        );
    }
}
