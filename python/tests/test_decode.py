"""Decode-path semantics: windowed KV-cache decoding == training forward.

The invariant: feeding a sequence through the stage decoders in *any*
window decomposition (prefill chunks, single tokens, KV-recompute windows)
produces the same hidden states as the monolithic training forward pass —
this is what makes the Rust inference engine's early-exit bookkeeping sound.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from numpy.testing import assert_allclose

from compile import configs, decode, model
from .conftest import init_params

ATOL = 2e-4


def _setup(rng, name="ee-tiny"):
    cfg = configs.presets()[name]
    P = cfg.pipeline_stages
    params = [init_params(rng, model.stage_param_specs(cfg, s))
              for s in range(P)]
    toks = jnp.asarray(rng.integers(0, 256, (1, cfg.seq)), jnp.int32)
    return cfg, params, toks


def _train_hidden(cfg, params, toks):
    """Last-stage output hidden states from the training forward path."""
    cur = toks
    for s in range(cfg.pipeline_stages):
        cur = model.stage_fwd(cfg, s, params[s], cur)
    return cur[0]  # (S, H)


def _decode_all(cfg, params, toks, widths):
    """Feed toks through stage decoders in windows of the given widths."""
    P = cfg.pipeline_stages
    per = cfg.n_layers // P
    caches = [jnp.zeros((per, 2, cfg.max_seq, cfg.n_heads, cfg.head_dim),
                        jnp.float32) for _ in range(P)]
    fns = [decode.stage_decode_fn(cfg, s) for s in range(P)]
    outs = []
    pos = 0
    seq = toks.shape[1]
    wi = 0
    while pos < seq:
        w = widths[wi % len(widths)]
        wi += 1
        w = min(w, seq - pos)
        x = toks[0, pos:pos + w]
        for s in range(P):
            x, caches[s] = fns[s](params[s], x, caches[s],
                                  jnp.int32(pos))
        outs.append(x)
        pos += w
    return jnp.concatenate(outs, axis=0), caches


def test_decode_w1_matches_training_forward(rng):
    cfg, params, toks = _setup(rng)
    want = _train_hidden(cfg, params, toks)
    got, _ = _decode_all(cfg, params, toks, widths=[1])
    assert_allclose(np.asarray(got), np.asarray(want), atol=ATOL, rtol=1e-3)


def test_decode_mixed_windows_match(rng):
    """Chunked prefill + singles + recompute-width windows all agree."""
    cfg, params, toks = _setup(rng)
    want = _train_hidden(cfg, params, toks)
    for widths in ([4], [8, 1], [4, 1, 1, 4]):
        got, _ = _decode_all(cfg, params, toks, widths=widths)
        assert_allclose(np.asarray(got), np.asarray(want), atol=ATOL,
                        rtol=1e-3, err_msg=str(widths))


def test_decode_windows_fill_identical_caches(rng):
    cfg, params, toks = _setup(rng)
    _, c1 = _decode_all(cfg, params, toks, widths=[1])
    _, c2 = _decode_all(cfg, params, toks, widths=[4])
    seq = toks.shape[1]
    for a, b in zip(c1, c2):
        assert_allclose(np.asarray(a[:, :, :seq]), np.asarray(b[:, :, :seq]),
                        atol=ATOL, rtol=1e-3)


def test_decode_recompute_is_idempotent(rng):
    """Re-decoding the same window (KV recomputation) rewrites identical KV
    and produces identical hiddens — healing a deficit is a no-op for
    already-healed positions."""
    cfg, params, toks = _setup(rng)
    fns = [decode.stage_decode_fn(cfg, s) for s in range(cfg.pipeline_stages)]
    per = cfg.n_layers // cfg.pipeline_stages
    caches = [jnp.zeros((per, 2, cfg.max_seq, cfg.n_heads, cfg.head_dim))
              for _ in range(cfg.pipeline_stages)]
    # Fill positions 0..3.
    x = toks[0, :4]
    for s in range(cfg.pipeline_stages):
        x, caches[s] = fns[s](params[s], x, caches[s], jnp.int32(0))
    first = x
    # Recompute the same window.
    x = toks[0, :4]
    for s in range(cfg.pipeline_stages):
        x, caches[s] = fns[s](params[s], x, caches[s], jnp.int32(0))
    assert_allclose(np.asarray(first), np.asarray(x), atol=1e-6)


def test_batched_decode_matches_solo_lanes(rng):
    """Lane-fused batched decode == B independent width-1 solo decodes.

    Lanes sit at *different* positions with *different* cache contents —
    the serving-pool case — and the fused step must reproduce each
    lane's solo hidden state and updated KV cache exactly (it is the
    same maths vmapped over the lane axis)."""
    cfg, params, toks = _setup(rng)
    P = cfg.pipeline_stages
    per = cfg.n_layers // P
    B = 3
    solo = [decode.stage_decode_fn(cfg, s) for s in range(P)]
    batched = [decode.stage_decode_batched_fn(cfg, s) for s in range(P)]
    # Per-lane prefill to distinct depths via the solo path.
    depths = [2, 5, 9]
    caches = [[jnp.zeros((per, 2, cfg.max_seq, cfg.n_heads, cfg.head_dim),
                         jnp.float32) for _ in range(P)] for _ in range(B)]
    for i, d in enumerate(depths):
        x = toks[0, :d]
        for s in range(P):
            x, caches[i][s] = solo[s](params[s], x, caches[i][s],
                                      jnp.int32(0))
    # One fused step: lane i decodes position depths[i].
    lane_toks = jnp.asarray([int(toks[0, d]) for d in depths], jnp.int32)
    pos = jnp.asarray(depths, jnp.int32)
    x_b = lane_toks
    new_caches_b = []
    for s in range(P):
        stacked = jnp.stack([caches[i][s] for i in range(B)])
        x_b, out_c = batched[s](params[s], x_b, stacked, pos)
        new_caches_b.append(out_c)
    # The same step, lane by lane, through the solo executables.
    for i, d in enumerate(depths):
        x = toks[0, d:d + 1]
        for s in range(P):
            x, caches[i][s] = solo[s](params[s], x, caches[i][s],
                                      jnp.int32(d))
        assert_allclose(np.asarray(x_b[i]), np.asarray(x[0]),
                        atol=1e-5, rtol=1e-5, err_msg=f"lane {i} hidden")
        for s in range(P):
            assert_allclose(np.asarray(new_caches_b[s][i]),
                            np.asarray(caches[i][s]),
                            atol=1e-5, rtol=1e-5,
                            err_msg=f"lane {i} stage {s} cache")


def test_batched_decode_lanes_are_independent(rng):
    """A lane's output must not depend on what rides in the other lanes
    (no cross-lane attention or cache bleed)."""
    cfg, params, toks = _setup(rng)
    P = cfg.pipeline_stages
    per = cfg.n_layers // P
    batched = [decode.stage_decode_batched_fn(cfg, s) for s in range(P)]

    def run(lane_toks, pos, caches):
        x = lane_toks
        outs = []
        for s in range(P):
            x, c = batched[s](params[s], x, caches[s], pos)
            outs.append(c)
        return x, outs

    caches = [jnp.zeros((2, per, 2, cfg.max_seq, cfg.n_heads, cfg.head_dim),
                        jnp.float32) for _ in range(P)]
    pos = jnp.asarray([0, 0], jnp.int32)
    a, _ = run(jnp.asarray([5, 7], jnp.int32), pos, caches)
    b, _ = run(jnp.asarray([5, 200], jnp.int32), pos, caches)
    assert_allclose(np.asarray(a[0]), np.asarray(b[0]), atol=1e-6,
                    err_msg="lane 0 depends on lane 1's token")
    assert not np.allclose(np.asarray(a[1]), np.asarray(b[1])), \
        "lane 1 ignored its own token"


def test_head_decode_matches_head_logits(rng):
    cfg, params, _ = _setup(rng)
    s = 1  # ee-tiny: stage 1 owns the early exit (layer 2) + final (4)
    for layer, kind, _w in model.stage_exits(cfg, s):
        fn, idx = decode.head_decode_fn(cfg, s, layer, kind)
        x = jnp.asarray(rng.normal(0, 1, (cfg.hidden,)), jnp.float32)
        head_params = [params[s][i] for i in idx]
        got = fn(head_params, x)[0]
        specs = model.stage_param_specs(cfg, s)
        pd = model.params_as_dict(specs, params[s])
        want = model.head_logits(cfg, pd, layer, kind, x[None])[0]
        assert_allclose(np.asarray(got), np.asarray(want), atol=1e-5,
                        rtol=1e-5)


def test_batched_head_matches_solo_head_decode(rng):
    """Lane-batched exit head == B solo `head_decode_fn` calls, and each
    lane's logits ignore what rides in the other lanes.

    This is the contract the Rust engine's device-resident lane groups
    lean on: one `s{s}_head{L}_b{B}` dispatch decides every lane in a
    fused group, and the decision per lane must be exactly the solo one
    (fired lanes ride along as padding without perturbing the rest)."""
    cfg, params, _ = _setup(rng)
    s = 1  # ee-tiny: stage 1 owns the early exit (layer 2) + final (4)
    B = 3
    for layer, kind, _w in model.stage_exits(cfg, s):
        solo, idx = decode.head_decode_fn(cfg, s, layer, kind)
        batched, bidx = decode.head_decode_batched_fn(cfg, s, layer, kind)
        assert bidx == idx
        head_params = [params[s][i] for i in idx]
        xs = jnp.asarray(rng.normal(0, 1, (B, cfg.hidden)), jnp.float32)
        got = batched(head_params, xs)[0]
        assert got.shape == (B, cfg.vocab)
        for i in range(B):
            want = solo(head_params, xs[i])[0]
            assert_allclose(np.asarray(got[i]), np.asarray(want),
                            atol=1e-5, rtol=1e-5,
                            err_msg=f"layer {layer} lane {i}")
        # Lane independence: perturbing lane 2 leaves lanes 0-1 intact.
        xs2 = xs.at[2].set(-xs[2])
        got2 = batched(head_params, xs2)[0]
        assert_allclose(np.asarray(got2[:2]), np.asarray(got[:2]),
                        atol=1e-6, err_msg=f"layer {layer} cross-lane bleed")
        assert not np.allclose(np.asarray(got2[2]), np.asarray(got[2])), \
            "lane 2 ignored its own hidden state"


def test_exit_logits_equal_truncated_model(rng):
    """Early-exit logits == logits of a model truncated at the exit layer.

    This is the semantic the paper's Figure 1 promises: exit e applies its
    head to the hidden state after backbone layer L_e.
    """
    cfg, params, toks = _setup(rng)
    # Hidden after layer 2 == input of stage 1 (exit is entry-normalised).
    x0 = model.stage_fwd(cfg, 0, params[0], toks)
    specs1 = model.stage_param_specs(cfg, 1)
    pd1 = model.params_as_dict(specs1, params[1])
    want = model.head_logits(cfg, pd1, 2, "bare", x0[0, -1][None])[0]

    fn, idx = decode.head_decode_fn(cfg, 1, 2, "bare")
    # Reach the same hidden via decoders.
    got_x, _ = _decode_all(cfg, params, toks, widths=[1])
    # got_x is last-stage output; we need stage-1 input. Recompute:
    fns0 = decode.stage_decode_fn(cfg, 0)
    per = cfg.n_layers // cfg.pipeline_stages
    cache = jnp.zeros((per, 2, cfg.max_seq, cfg.n_heads, cfg.head_dim))
    xs = []
    for pos in range(toks.shape[1]):
        x, cache = fns0(params[0], toks[0, pos:pos + 1], cache,
                        jnp.int32(pos))
        xs.append(x[0])
    got = fn([params[1][i] for i in idx], xs[-1])[0]
    assert_allclose(np.asarray(got), np.asarray(want), atol=ATOL, rtol=1e-3)


def test_decode_position_embedding_offset(rng):
    """Tokens at position p must use pos-embedding row p, not 0."""
    cfg, params, toks = _setup(rng)
    fns0 = decode.stage_decode_fn(cfg, 0)
    per = cfg.n_layers // cfg.pipeline_stages
    cache = jnp.zeros((per, 2, cfg.max_seq, cfg.n_heads, cfg.head_dim))
    x0, cache = fns0(params[0], toks[0, 0:1], cache, jnp.int32(0))
    x1, _ = fns0(params[0], toks[0, 0:1], cache, jnp.int32(1))
    # Same token at different positions -> different hidden states.
    assert np.abs(np.asarray(x0) - np.asarray(x1)).max() > 1e-4
