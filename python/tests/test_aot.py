"""AOT bridge sanity: manifests are complete and HLO text is loadable.

Checks the contract the Rust runtime relies on: every executable referenced
by a manifest exists, parses as HLO text, and declares the parameter/output
arity the manifest promises.
"""

import json
import os
import re

import pytest

from compile import configs, model, optim

ART = os.path.join(os.path.dirname(__file__), "..", "..", "artifacts")

pytestmark = pytest.mark.skipif(
    not os.path.isdir(ART), reason="run `make artifacts` first")


def _manifests():
    out = {}
    for name in os.listdir(ART):
        mf = os.path.join(ART, name, "manifest.json")
        if os.path.isfile(mf):
            with open(mf) as f:
                out[name] = json.load(f)
    return out


def test_all_presets_have_manifests():
    have = set(_manifests())
    want = set(configs.presets())
    assert want <= have, want - have


@pytest.mark.parametrize("name", list(configs.presets()))
def test_manifest_files_exist_and_parse(name):
    mans = _manifests()
    if name not in mans:
        pytest.skip("artifacts not built for this preset")
    man = mans[name]
    for st in man["stages"]:
        for ename, fname in st["executables"].items():
            path = os.path.join(ART, name, fname)
            assert os.path.isfile(path), (ename, fname)
            head = open(path).read(200)
            assert head.startswith("HloModule"), (ename, head[:40])
    if man["reference"]:
        for key in ("loss_grads", "eval"):
            path = os.path.join(ART, name, man["reference"][key])
            assert os.path.isfile(path)


def _count_hlo_params(path):
    """Count parameter instructions of the ENTRY computation."""
    text = open(path).read()
    m = re.search(r"^ENTRY \S+ \{(.*?)^\}", text, re.M | re.S)
    assert m, path
    return len(re.findall(r"= \S+ parameter\(\d+\)", m.group(1)))


def test_bwd_arity_matches_manifest():
    mans = _manifests()
    man = mans.get("ee-tiny")
    if man is None:
        pytest.skip("ee-tiny artifacts missing")
    for st in man["stages"]:
        n_p = st["n_params"]
        n_e = st["n_exits"]
        path = os.path.join(ART, "ee-tiny", st["executables"]["bwd"])
        got = _count_hlo_params(path)
        # params + x_in + targets + (weights if exits) + g_out
        want = n_p + 2 + (1 if n_e > 0 else 0) + 1
        assert got == want, (st["index"], got, want)


def test_adam_arity():
    mans = _manifests()
    man = mans.get("ee-tiny")
    if man is None:
        pytest.skip("ee-tiny artifacts missing")
    for st in man["stages"]:
        path = os.path.join(ART, "ee-tiny", st["executables"]["adam"])
        assert _count_hlo_params(path) == 3 + 4 * st["n_params"]


def test_param_specs_match_manifest():
    mans = _manifests()
    for name, cfg in configs.presets().items():
        if name not in mans:
            continue
        man = mans[name]
        for s in range(cfg.pipeline_stages):
            specs = model.stage_param_specs(cfg, s)
            got = man["stages"][s]["params"]
            assert [g["name"] for g in got] == [sp.name for sp in specs]
            assert [tuple(g["shape"]) for g in got] == \
                [sp.shape for sp in specs]


def test_exit_metadata_entry_flags():
    """All preset exits must be entry-normalised (Optimization 2) so the
    decode engines can evaluate heads at stage boundaries."""
    mans = _manifests()
    for name in configs.presets():
        if name not in mans:
            continue
        for st in mans[name]["stages"]:
            for e in st["exits"]:
                assert e["final"] or e["entry"], (name, e)
