import os
import sys

import jax
import numpy as np
import pytest

# Make `compile` importable when pytest runs from python/ or the repo root.
sys.path.insert(0, os.path.dirname(os.path.dirname(os.path.abspath(__file__))))

jax.config.update("jax_enable_x64", False)


@pytest.fixture
def rng():
    return np.random.default_rng(1234)


def init_params(rng, specs):
    """Initialise a param list per its specs (mirrors the Rust initialiser)."""
    import jax.numpy as jnp
    out = []
    for sp in specs:
        if sp.init == "normal":
            a = rng.normal(0.0, sp.std, sp.shape)
        elif sp.init == "ones":
            a = np.ones(sp.shape)
        elif sp.init == "zeros":
            a = np.zeros(sp.shape)
        else:
            raise ValueError(sp.init)
        out.append(jnp.asarray(a, jnp.float32))
    return out
