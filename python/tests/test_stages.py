"""L2 pipeline-stage semantics: the paper's Proposition 3.1 in numbers.

Checks that chaining per-stage forward + auxiliary-loss backward executables
(the functions that get AOT-lowered) reproduces the monolithic model's
losses and gradients exactly, for every preset config — including tied
embeddings and mid-stage exits.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from numpy.testing import assert_allclose

from compile import configs, model
from compile.configs import ExitSpec, PAD_ID
from .conftest import init_params


def _data(rng, cfg):
    tokens = jnp.asarray(rng.integers(0, 256, (cfg.microbatch, cfg.seq)),
                         jnp.int32)
    targets = jnp.asarray(rng.integers(0, 256, (cfg.microbatch, cfg.seq)),
                          jnp.int32)
    return tokens, targets


def _pipeline_loss_grads(cfg, stage_params, tokens, targets, weights):
    """Run the fwd chain then the aux-loss bwd chain (Eq. 2)."""
    P = cfg.pipeline_stages
    xs = [None] * P  # stage inputs
    cur = tokens
    for s in range(P):
        xs[s] = cur
        cur = model.stage_fwd(cfg, s, stage_params[s], cur)
    x_out_last = cur

    g = jnp.zeros_like(x_out_last)
    all_losses = [None] * P
    all_grads = [None] * P
    wpos = len(weights)
    for s in reversed(range(P)):
        n_exits = len(model.stage_exits(cfg, s))
        w_s = jnp.asarray(weights[wpos - n_exits:wpos], jnp.float32)
        wpos -= n_exits
        bwd = model.stage_aux_grads(cfg, s)
        out = bwd(stage_params[s], xs[s], targets, w_s, g)
        losses = out[0]
        if s == 0:
            grads = out[1:]
            g = None
        else:
            g = out[1]
            grads = out[2:]
        all_losses[s] = losses
        all_grads[s] = list(grads)
    flat_losses = jnp.concatenate(all_losses)
    flat_grads = [t for gs in all_grads for t in gs]
    return flat_losses, flat_grads


def _check_config(cfg, rng, atol=5e-5):
    P = cfg.pipeline_stages
    stage_params = [init_params(rng, model.stage_param_specs(cfg, s))
                    for s in range(P)]
    all_params = [p for sp in stage_params for p in sp]
    tokens, targets = _data(rng, cfg)
    weights = [w for s in range(P)
               for (_, _, w) in model.stage_exits(cfg, s)]

    full = model.full_loss_grads_fn(cfg)
    out = full(all_params, tokens, targets, jnp.asarray(weights))
    losses_ref, grads_ref = out[0], out[1:]

    losses_pipe, grads_pipe = _pipeline_loss_grads(
        cfg, stage_params, tokens, targets, weights)

    assert_allclose(np.asarray(losses_pipe), np.asarray(losses_ref),
                    atol=1e-5, rtol=1e-5)
    assert len(grads_pipe) == len(grads_ref)
    for a, b in zip(grads_pipe, grads_ref):
        assert_allclose(np.asarray(a), np.asarray(b), atol=atol, rtol=1e-4)


@pytest.mark.parametrize("name", ["ee-tiny", "ee-tiny-tied", "ee-small"])
def test_pipeline_equals_full_model(name, rng):
    _check_config(configs.presets()[name], rng)


def test_pipeline_equals_full_model_midstage_exit(rng):
    """An exit in the middle of a stage (not Optimization-2 normalised)."""
    cfg = configs.ModelConfig(
        name="midstage", hidden=32, n_layers=4, n_heads=2, seq=16,
        max_seq=16, microbatch=2, pipeline_stages=2,
        early_exits=[configs.ExitSpec(layer=1, head="norm", weight=0.3),
                     configs.ExitSpec(layer=3, head="mlp", weight=0.7)],
    ).validate()
    _check_config(cfg, rng)


def test_pipeline_equals_full_model_no_pallas(rng):
    cfg = configs.ModelConfig(
        name="nopallas", hidden=32, n_layers=4, n_heads=2, seq=16,
        max_seq=16, microbatch=2, pipeline_stages=4,
        early_exits=[configs.ExitSpec(layer=1, head="bare", weight=0.5)],
        use_pallas=False,
    ).validate()
    _check_config(cfg, rng)


def test_gradient_vs_finite_difference(rng):
    """Spot-check the whole stack against central differences."""
    cfg = configs.ModelConfig(
        name="fd", hidden=16, n_layers=2, n_heads=2, seq=8, max_seq=8,
        microbatch=1, pipeline_stages=2,
        early_exits=[configs.ExitSpec(layer=1, head="bare", weight=0.5)],
    ).validate()
    stage_params = [init_params(rng, model.stage_param_specs(cfg, s))
                    for s in range(2)]
    all_params = [p for sp in stage_params for p in sp]
    tokens, targets = _data(rng, cfg)
    w = jnp.asarray([0.5, 1.0])

    loss_fn = model.full_loss_fn(cfg)
    grads = model.full_loss_grads_fn(cfg)(all_params, tokens, targets, w)[1:]

    # Perturb a few entries of the first attention matrix (param idx 4).
    idx = 4
    eps = 1e-3
    flat = np.asarray(all_params[idx]).ravel()
    g_flat = np.asarray(grads[idx]).ravel()
    for k in [0, 7, len(flat) // 2]:
        pp, pm = flat.copy(), flat.copy()
        pp[k] += eps
        pm[k] -= eps
        ap = list(all_params)
        ap[idx] = jnp.asarray(pp.reshape(all_params[idx].shape))
        lp = float(loss_fn(ap, tokens, targets, w)[0])
        ap[idx] = jnp.asarray(pm.reshape(all_params[idx].shape))
        lm = float(loss_fn(ap, tokens, targets, w)[0])
        fd = (lp - lm) / (2 * eps)
        assert abs(fd - g_flat[k]) < 5e-3, (k, fd, g_flat[k])


def test_pad_targets_are_masked(rng):
    cfg = configs.presets()["ee-tiny"]
    stage_params = [init_params(rng, model.stage_param_specs(cfg, s))
                    for s in range(2)]
    all_params = [p for sp in stage_params for p in sp]
    tokens, targets = _data(rng, cfg)
    w = jnp.asarray([0.5, 1.0])
    full = model.full_loss_fn(cfg)
    l_all = np.asarray(full(all_params, tokens, targets, w)[1])
    # Mask the second half of every row: loss changes (different mean),
    # but remains finite; fully padded targets give zero loss.
    t2 = targets.at[:, cfg.seq // 2:].set(PAD_ID)
    l_half = np.asarray(full(all_params, tokens, t2, w)[1])
    assert np.isfinite(l_half).all() and not np.allclose(l_all, l_half)
    t3 = jnp.full_like(targets, PAD_ID)
    l_none = np.asarray(full(all_params, tokens, t3, w)[1])
    assert_allclose(l_none, 0.0, atol=1e-6)


def test_weight_zero_kills_exit_gradient(rng):
    """With w_early = 0 the early head receives no gradient."""
    cfg = configs.presets()["ee-tiny"]
    stage_params = [init_params(rng, model.stage_param_specs(cfg, s))
                    for s in range(2)]
    tokens, targets = _data(rng, cfg)
    losses, grads = _pipeline_loss_grads(cfg, stage_params, tokens, targets,
                                         [0.0, 1.0])
    specs = (model.full_param_specs(cfg))
    for sp, g in zip(specs, grads):
        if "exit2" in sp.name:
            assert np.abs(np.asarray(g)).max() == 0.0, sp.name
        if "exit4" in sp.name:  # final head must still learn
            assert np.abs(np.asarray(g)).max() > 0.0, sp.name


def test_exit_order_is_stage_major_sorted(rng):
    cfg = configs.presets()["ee-small"]
    order = [(s, l) for s in range(cfg.pipeline_stages)
             for (l, _, _) in model.stage_exits(cfg, s)]
    layers = [l for _, l in order]
    assert layers == sorted(layers)
    assert layers[-1] == cfg.n_layers  # final exit last


def test_stage_param_partition_is_exhaustive():
    for name, cfg in configs.presets().items():
        full = model.full_param_specs(cfg)
        per_stage = sum((model.stage_param_specs(cfg, s)
                         for s in range(cfg.pipeline_stages)), [])
        assert len(full) == len(per_stage)
        got = sorted(sp.name for sp in per_stage)
        assert len(set(got)) == len(got), f"{name}: duplicate param name"


def test_param_count_formula_matches_specs():
    for name, cfg in configs.presets().items():
        n = sum(int(np.prod(sp.shape))
                for sp in model.full_param_specs(cfg))
        assert n == configs.param_count(cfg), name
