"""L1 kernel correctness: every Pallas kernel vs its pure-jnp oracle.

Hypothesis sweeps shapes; assert_allclose against ref.py. This is the CORE
correctness signal for the compute layer — everything the Rust runtime
executes lowers through these kernels.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st
from numpy.testing import assert_allclose

from compile.kernels import attention, exit_loss, norm, ref

SETTINGS = dict(max_examples=12, deadline=None)


def _rand(key, shape, scale=1.0):
    return jax.random.normal(key, shape, jnp.float32) * scale


# ---------------------------------------------------------------------------
# Flash attention
# ---------------------------------------------------------------------------

@settings(**SETTINGS)
@given(
    b=st.sampled_from([1, 2]),
    s=st.sampled_from([8, 16, 32, 64, 128]),
    h=st.sampled_from([1, 2, 4]),
    d=st.sampled_from([8, 16, 32]),
    seed=st.integers(0, 2**31 - 1),
)
def test_flash_attention_fwd(b, s, h, d, seed):
    ks = jax.random.split(jax.random.PRNGKey(seed), 3)
    q, k, v = (_rand(kk, (b, s, h, d)) for kk in ks)
    got = attention.flash_attention(q, k, v)
    want = ref.causal_attention(q, k, v)
    assert_allclose(np.asarray(got), np.asarray(want), atol=2e-5, rtol=2e-5)


@settings(**SETTINGS)
@given(
    s=st.sampled_from([8, 16, 64]),
    d=st.sampled_from([8, 16]),
    seed=st.integers(0, 2**31 - 1),
)
def test_flash_attention_grads(s, d, seed):
    b, h = 2, 2
    ks = jax.random.split(jax.random.PRNGKey(seed), 4)
    q, k, v = (_rand(kk, (b, s, h, d)) for kk in ks[:3])
    ct = _rand(ks[3], (b, s, h, d))

    def loss_pallas(q, k, v):
        return (attention.flash_attention(q, k, v) * ct).sum()

    def loss_ref(q, k, v):
        return (ref.causal_attention(q, k, v) * ct).sum()

    g1 = jax.grad(loss_pallas, argnums=(0, 1, 2))(q, k, v)
    g2 = jax.grad(loss_ref, argnums=(0, 1, 2))(q, k, v)
    for a, b_ in zip(g1, g2):
        assert_allclose(np.asarray(a), np.asarray(b_), atol=5e-5, rtol=5e-4)


def test_flash_attention_is_causal():
    """Future tokens must not influence earlier outputs."""
    b, s, h, d = 1, 16, 2, 8
    key = jax.random.PRNGKey(0)
    ks = jax.random.split(key, 3)
    q, k, v = (_rand(kk, (b, s, h, d)) for kk in ks)
    o1 = attention.flash_attention(q, k, v)
    # Perturb the last token's k/v: outputs at positions < s-1 unchanged.
    k2 = k.at[:, -1].set(k[:, -1] + 100.0)
    v2 = v.at[:, -1].set(v[:, -1] - 50.0)
    o2 = attention.flash_attention(q, k2, v2)
    assert_allclose(np.asarray(o1[:, :-1]), np.asarray(o2[:, :-1]),
                    atol=1e-6)
    assert not np.allclose(np.asarray(o1[:, -1]), np.asarray(o2[:, -1]))


# ---------------------------------------------------------------------------
# Fused exit loss (unembed + streaming-LSE cross-entropy)
# ---------------------------------------------------------------------------

@settings(**SETTINGS)
@given(
    n=st.sampled_from([8, 32, 64, 128, 256]),
    h=st.sampled_from([16, 64, 128]),
    v=st.sampled_from([64, 320, 512]),
    seed=st.integers(0, 2**31 - 1),
)
def test_exit_loss_fwd(n, h, v, seed):
    ks = jax.random.split(jax.random.PRNGKey(seed), 4)
    x = _rand(ks[0], (n, h))
    w = _rand(ks[1], (h, v), scale=0.05)
    t = jax.random.randint(ks[2], (n,), 0, v)
    valid = (jax.random.uniform(ks[3], (n,)) > 0.25).astype(jnp.float32)
    got = exit_loss.exit_loss_mean(x, w, t, valid)
    want = ref.exit_loss(x, w, t, valid)[0]
    assert_allclose(float(got), float(want), atol=1e-5, rtol=1e-5)
    per = exit_loss.exit_loss_per_token(x, w, t, valid)
    per_ref = ref.exit_loss(x, w, t, valid)[1]
    assert_allclose(np.asarray(per), np.asarray(per_ref), atol=1e-5,
                    rtol=1e-5)


@settings(**SETTINGS)
@given(
    n=st.sampled_from([8, 64]),
    h=st.sampled_from([16, 64]),
    v=st.sampled_from([64, 320]),
    seed=st.integers(0, 2**31 - 1),
)
def test_exit_loss_grads(n, h, v, seed):
    ks = jax.random.split(jax.random.PRNGKey(seed), 4)
    x = _rand(ks[0], (n, h))
    w = _rand(ks[1], (h, v), scale=0.05)
    t = jax.random.randint(ks[2], (n,), 0, v)
    valid = jnp.ones((n,), jnp.float32)
    g1 = jax.grad(exit_loss.exit_loss_mean, argnums=(0, 1))(x, w, t, valid)
    g2 = jax.grad(lambda *a: ref.exit_loss(*a)[0], argnums=(0, 1))(
        x, w, t, valid)
    for a, b_ in zip(g1, g2):
        assert_allclose(np.asarray(a), np.asarray(b_), atol=2e-5, rtol=1e-4)


def test_exit_loss_pad_positions_contribute_zero():
    n, h, v = 32, 16, 64
    key = jax.random.PRNGKey(7)
    ks = jax.random.split(key, 3)
    x = _rand(ks[0], (n, h))
    w = _rand(ks[1], (h, v), scale=0.1)
    t = jax.random.randint(ks[2], (n,), 0, v)
    valid = jnp.zeros((n,), jnp.float32).at[: n // 2].set(1.0)
    # Mean over first half only == masked mean over all.
    m1 = exit_loss.exit_loss_mean(x[: n // 2], w, t[: n // 2],
                                  jnp.ones((n // 2,)))
    m2 = exit_loss.exit_loss_mean(x, w, t, valid)
    assert_allclose(float(m1), float(m2), atol=1e-6)
    # Gradient w.r.t. masked-out rows of x must be exactly zero.
    gx = jax.grad(exit_loss.exit_loss_mean)(x, w, t, valid)
    assert np.abs(np.asarray(gx[n // 2:])).max() == 0.0


def test_exit_loss_all_pad_is_finite():
    n, h, v = 8, 16, 64
    x = jnp.ones((n, h))
    w = jnp.ones((h, v)) * 0.1
    t = jnp.zeros((n,), jnp.int32)
    valid = jnp.zeros((n,), jnp.float32)
    m = exit_loss.exit_loss_mean(x, w, t, valid)
    assert float(m) == 0.0
    gx, gw = jax.grad(exit_loss.exit_loss_mean, argnums=(0, 1))(
        x, w, t, valid)
    assert np.isfinite(np.asarray(gx)).all()
    assert np.isfinite(np.asarray(gw)).all()


def test_exit_loss_matches_known_value():
    """Uniform logits -> loss == log(V) exactly."""
    n, h, v = 8, 4, 64
    x = jnp.zeros((n, h))
    w = jnp.zeros((h, v))
    t = jnp.arange(n, dtype=jnp.int32)
    valid = jnp.ones((n,))
    m = exit_loss.exit_loss_mean(x, w, t, valid)
    assert_allclose(float(m), float(np.log(v)), rtol=1e-6)


# ---------------------------------------------------------------------------
# LayerNorm
# ---------------------------------------------------------------------------

@settings(**SETTINGS)
@given(
    rows=st.sampled_from([1, 8, 64, 256]),
    h=st.sampled_from([8, 64, 384]),
    seed=st.integers(0, 2**31 - 1),
)
def test_layer_norm_fwd(rows, h, seed):
    ks = jax.random.split(jax.random.PRNGKey(seed), 3)
    x = _rand(ks[0], (rows, h), scale=3.0)
    g = _rand(ks[1], (h,)) + 1.0
    b = _rand(ks[2], (h,))
    got = norm.layer_norm(x, g, b)
    want = ref.layer_norm(x, g, b)
    assert_allclose(np.asarray(got), np.asarray(want), atol=2e-6, rtol=2e-5)


@settings(**SETTINGS)
@given(seed=st.integers(0, 2**31 - 1))
def test_layer_norm_grads(seed):
    rows, h = 16, 32
    ks = jax.random.split(jax.random.PRNGKey(seed), 4)
    x = _rand(ks[0], (rows, h), scale=2.0)
    g = _rand(ks[1], (h,)) + 1.0
    b = _rand(ks[2], (h,))
    ct = _rand(ks[3], (rows, h))

    def f(fn):
        return lambda x, g, b: (fn(x, g, b) * ct).sum()

    g1 = jax.grad(f(norm.layer_norm), argnums=(0, 1, 2))(x, g, b)
    g2 = jax.grad(f(ref.layer_norm), argnums=(0, 1, 2))(x, g, b)
    for a, b_ in zip(g1, g2):
        assert_allclose(np.asarray(a), np.asarray(b_), atol=2e-5, rtol=1e-4)


def test_layer_norm_3d_batch():
    x = jax.random.normal(jax.random.PRNGKey(0), (2, 8, 16))
    g, b = jnp.ones(16), jnp.zeros(16)
    got = norm.layer_norm(x, g, b)
    want = ref.layer_norm(x, g, b)
    assert_allclose(np.asarray(got), np.asarray(want), atol=2e-6)
