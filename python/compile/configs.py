"""Model / pipeline configurations for EE-LLM artifact generation.

A config fully determines the set of AOT artifacts: per-stage forward,
auxiliary-loss backward (the paper's Eq. 2 contract), windowed decode with
KV cache, Adam update, and (for small configs) a monolithic full-model
reference used by the Rust integration tests.

Exit placement follows the paper's Optimization 2 (Appendix A.2): an early
exit "after layer L" is normalised to the *beginning* of the stage that owns
layer L+1, so every exit head reads the stage's input hidden state. An exit
at layer 0 sits on the embedding output (first stage), as in the paper's
third exit of Section 5.1.
"""

from dataclasses import dataclass, field, asdict

# Byte-level tokenizer: 256 raw bytes + PAD/BOS/EOS, padded to a multiple of
# 64 for friendly GEMM tiling in the fused exit-loss kernel.
PAD_ID = 256
BOS_ID = 257
EOS_ID = 258
VOCAB_SIZE = 320

HEAD_KINDS = ("bare", "norm", "mlp")


@dataclass
class ExitSpec:
    """An early (or final) exit head.

    layer: backbone layer index the exit is attached *after* (0 = on the
        embedding output, n_layers = the final exit).
    head: one of HEAD_KINDS; the final exit is always "norm" (LN + unembed),
        matching GPT's final LayerNorm.
    weight: default training loss weight (runtime-overridable input).
    """

    layer: int
    head: str = "bare"
    weight: float = 1.0

    def __post_init__(self):
        assert self.head in HEAD_KINDS, self.head


@dataclass
class ModelConfig:
    name: str
    hidden: int = 64
    n_layers: int = 4
    n_heads: int = 4
    seq: int = 64              # training sequence length
    max_seq: int = 64          # KV-cache capacity for decoding
    vocab: int = VOCAB_SIZE
    microbatch: int = 2        # training microbatch size
    pipeline_stages: int = 2
    early_exits: list = field(default_factory=list)  # list[ExitSpec]
    tie_embeddings: bool = False
    use_pallas: bool = True
    decode_widths: list = field(default_factory=lambda: [1, 4])
    prefill_width: int = 16
    # Lane-fused batched decode: each entry B emits a per-stage
    # `s{s}_decode_b{B}_w1` executable stepping B independent width-1
    # windows (one per live decode session) in a single XLA call, with
    # lane-stacked KV caches and a per-lane position vector. The serving
    # pool fuses same-policy sessions into the largest lane group that
    # fits; sessions with a recompute deficit fall back to the solo
    # windowed executables above.
    decode_lanes: list = field(default_factory=lambda: [2, 4])
    # Emit the monolithic full-model reference executables (tests only;
    # too large for big configs).
    emit_reference: bool = True

    @property
    def head_dim(self):
        assert self.hidden % self.n_heads == 0
        return self.hidden // self.n_heads

    @property
    def ffn(self):
        return 4 * self.hidden

    def layers_of_stage(self, s):
        """Backbone layer indices (1-based) owned by stage s (0-based)."""
        assert self.n_layers % self.pipeline_stages == 0, (
            "layers must divide evenly across stages (Megatron convention)")
        per = self.n_layers // self.pipeline_stages
        return list(range(s * per + 1, (s + 1) * per + 1))

    def stage_of_exit(self, exit_spec):
        """Stage owning an exit, after Optimization-2 normalisation.

        Exit after layer L reads the hidden state *entering* layer L+1, so it
        lives at the beginning of the stage owning layer L+1. The final exit
        (layer == n_layers) lives at the end of the last stage.
        """
        if exit_spec.layer >= self.n_layers:
            return self.pipeline_stages - 1
        per = self.n_layers // self.pipeline_stages
        return exit_spec.layer // per

    def exits_of_stage(self, s):
        return [e for e in self.early_exits if self.stage_of_exit(e) == s]

    def validate(self):
        assert self.n_layers % self.pipeline_stages == 0
        assert self.hidden % self.n_heads == 0
        assert self.seq <= self.max_seq
        seen = set()
        for e in self.early_exits:
            assert 0 <= e.layer < self.n_layers, e
            assert e.layer not in seen, f"duplicate exit at layer {e.layer}"
            seen.add(e.layer)
        for w in self.decode_widths:
            assert w >= 1 and w <= self.max_seq
        assert 1 in self.decode_widths, "width-1 decode is required"
        # Lane sizes also key the batched exit-head executables
        # (`s{s}_head{L}_b{B}`): one per exit per lane size, so a fused
        # group's exit decisions cost one dispatch. Keep the ladder small
        # and bounded — every entry multiplies the artifact count.
        for b in self.decode_lanes:
            assert b >= 2, f"lane count {b} < 2 fuses nothing"
            assert b <= 64, f"lane count {b} > 64 blows up artifact size"
        assert len(set(self.decode_lanes)) == len(self.decode_lanes)
        return self

    def to_json(self):
        d = asdict(self)
        d["early_exits"] = [asdict(e) for e in self.early_exits]
        d["head_dim"] = self.head_dim
        d["ffn"] = self.ffn
        return d


def _mk(name, **kw):
    return ModelConfig(name=name, **kw).validate()


def presets():
    """All configs that `python -m compile.aot --all` materialises."""
    cfgs = [
        # Tiny config: drives the Rust unit/integration tests (fast to
        # compile and execute; reference executables emitted).
        _mk(
            "ee-tiny",
            hidden=64, n_layers=4, n_heads=4, seq=32, max_seq=256,
            microbatch=2, pipeline_stages=2,
            early_exits=[ExitSpec(layer=2, head="bare", weight=0.5)],
            decode_widths=[1, 2, 4, 8], prefill_width=8,
            decode_lanes=[2, 4, 8],
        ),
        # Tied variant: input embedding shared with every exit head
        # (paper Section 2, option 3). Exercises the cross-stage tied
        # gradient all-reduce path in the Rust trainer.
        _mk(
            "ee-tiny-tied",
            hidden=64, n_layers=4, n_heads=4, seq=32, max_seq=256,
            microbatch=2, pipeline_stages=2,
            early_exits=[ExitSpec(layer=0, head="bare", weight=0.25),
                         ExitSpec(layer=2, head="norm", weight=0.5)],
            tie_embeddings=True,
            decode_widths=[1, 2, 4, 8], prefill_width=8,
        ),
        # Small config: 4 pipeline stages, the paper's canonical layout
        # (exits at 1/4 and 1/2 depth, weights 0.25 / 0.5 — Section 5.1).
        _mk(
            "ee-small",
            hidden=128, n_layers=8, n_heads=4, seq=64, max_seq=256,
            microbatch=2, pipeline_stages=4,
            early_exits=[ExitSpec(layer=2, head="bare", weight=0.25),
                         ExitSpec(layer=4, head="bare", weight=0.5)],
            decode_widths=[1, 2, 4, 8], prefill_width=16,
        ),
        # MLP-head variant of ee-small (paper Appendix B.3 first model).
        _mk(
            "ee-small-mlp",
            hidden=128, n_layers=8, n_heads=4, seq=64, max_seq=256,
            microbatch=2, pipeline_stages=4,
            early_exits=[ExitSpec(layer=2, head="mlp", weight=0.25),
                         ExitSpec(layer=4, head="mlp", weight=0.5)],
            decode_widths=[1, 2, 4, 8], prefill_width=16,
            emit_reference=False,
        ),
        # E2E config: the end-to-end training example (examples/train_e2e.rs).
        # ~11M parameters; exits at 1/4 and 1/2 depth like the paper's 1.3B.
        _mk(
            "ee-e2e",
            hidden=384, n_layers=8, n_heads=6, seq=128, max_seq=320,
            microbatch=2, pipeline_stages=4,
            early_exits=[ExitSpec(layer=2, head="norm", weight=0.25),
                         ExitSpec(layer=4, head="norm", weight=0.5)],
            decode_widths=[1, 2, 4, 8], prefill_width=32,
            decode_lanes=[2, 4, 8], emit_reference=False,
        ),
    ]
    return {c.name: c for c in cfgs}


def param_count(cfg: ModelConfig) -> int:
    """Physical parameter count (tied heads store per-stage replicas, as in
    Megatron's tied input/output embeddings — replicas are counted)."""
    h, V, S, L = cfg.hidden, cfg.vocab, cfg.max_seq, cfg.n_layers
    n = V * h + S * h                       # embeddings
    n += L * (12 * h * h + 13 * h)          # blocks (qkv, proj, mlp, lns)
    n += 2 * h + h * V                      # final exit: ln + unembed
    for e in cfg.early_exits:
        n += h * V
        if e.head in ("norm", "mlp"):
            n += 2 * h
        if e.head == "mlp":
            n += 8 * h * h + 5 * h
    return n
