"""Row-wise fused LayerNorm as a Pallas kernel.

One grid step normalises a (bn, H) tile entirely in VMEM (single read of x,
single write of y — the fusion a GPU implementation gets from a warp-level
reduction). The backward pass uses the closed-form LayerNorm VJP in plain
jnp: it is a pair of row reductions XLA fuses well on every backend, and
keeping it out of Pallas keeps the kernel surface minimal (see DESIGN.md
§Perf for the measured non-impact).

Validated against kernels.ref.layer_norm by python/tests/test_kernels.py.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .util import INTERPRET, pick_block

EPS = 1e-5


def _ln_kernel(x_ref, g_ref, b_ref, y_ref, *, eps):
    x = x_ref[...]
    mean = x.mean(axis=-1, keepdims=True)
    var = ((x - mean) ** 2).mean(axis=-1, keepdims=True)
    xhat = (x - mean) * jax.lax.rsqrt(var + eps)
    y_ref[...] = xhat * g_ref[...] + b_ref[...]


def _ln_fwd_2d(x, gamma, beta):
    n, h = x.shape
    bn = pick_block(n, 256)
    y = pl.pallas_call(
        functools.partial(_ln_kernel, eps=EPS),
        grid=(n // bn,),
        in_specs=[
            pl.BlockSpec((bn, h), lambda i: (i, 0)),
            pl.BlockSpec((h,), lambda i: (0,)),
            pl.BlockSpec((h,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((bn, h), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((n, h), x.dtype),
        interpret=INTERPRET,
    )(x, gamma, beta)
    return y


@jax.custom_vjp
def layer_norm(x, gamma, beta):
    """Fused LayerNorm over the last axis. x: (..., H)."""
    shape = x.shape
    y = _ln_fwd_2d(x.reshape(-1, shape[-1]), gamma, beta)
    return y.reshape(shape)


def _fwd_rule(x, gamma, beta):
    return layer_norm(x, gamma, beta), (x, gamma, beta)


def _bwd_rule(res, dy):
    x, gamma, beta = res
    mean = x.mean(axis=-1, keepdims=True)
    var = ((x - mean) ** 2).mean(axis=-1, keepdims=True)
    rstd = jax.lax.rsqrt(var + EPS)
    xhat = (x - mean) * rstd
    dyg = dy * gamma
    h = x.shape[-1]
    dx = rstd * (dyg - dyg.mean(axis=-1, keepdims=True)
                 - xhat * (dyg * xhat).mean(axis=-1, keepdims=True))
    axes = tuple(range(x.ndim - 1))
    dgamma = (dy * xhat).sum(axis=axes)
    dbeta = dy.sum(axis=axes)
    del h
    return dx, dgamma, dbeta


layer_norm.defvjp(_fwd_rule, _bwd_rule)
