"""Shared helpers for the Pallas kernels.

All kernels run with ``interpret=True``: the CPU PJRT plugin cannot execute
Mosaic custom-calls, so interpret mode (which lowers to plain HLO) is the
correctness-and-interchange path. Real-TPU efficiency is assessed
structurally (VMEM footprint / MXU tiling of the BlockSpecs) in DESIGN.md.
"""

INTERPRET = True

# Finite stand-in for -inf: keeps running-max recurrences NaN-free when an
# entire block is causally masked (exp(-1e30 - m) underflows to 0 exactly).
NEG_INF = -1e30


def pick_block(n: int, preferred: int) -> int:
    """Largest power-of-two divisor of ``n`` that is <= ``preferred``.

    Falls back to ``n`` itself when ``n`` has no power-of-two factor below
    the preference (shapes here are multiples of 8, so this is rare).
    MXU-friendly tiles are 128-multiples; on small test shapes we simply
    take the whole axis.
    """
    b = 1
    while b * 2 <= min(n, preferred) and n % (b * 2) == 0:
        b *= 2
    return b if n % b == 0 else n
