"""Causal flash attention as a Pallas kernel (training hot path).

Forward: classic online-softmax streaming over key blocks — the TPU
re-thinking of the CUDA flash-attention schedule. The HBM<->VMEM movement a
GPU kernel expresses with threadblocks + shared memory is expressed here
with the grid + BlockSpec index maps: grid = (batch*heads, q_blocks,
k_blocks) with the key axis innermost, so each (bq, d) query tile stays
VMEM-resident while (bk, d) key/value tiles stream past it. Running max and
normaliser live in revisited output refs (VMEM accumulators).

Backward: one (batch*head) slice per grid step, recomputing probabilities
from the saved log-sum-exp (no s*s attention matrix is ever written to HBM
in either direction). For the sequence lengths in this repo (<= 256) a full
(s, s) tile fits VMEM comfortably (256^2 f32 = 256 KiB); DESIGN.md sketches
the k-block split for longer sequences.

Validated against kernels.ref.causal_attention (values and grads) by
python/tests/test_kernels.py.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .util import INTERPRET, NEG_INF, pick_block


def _fwd_kernel(q_ref, k_ref, v_ref, o_ref, lse_ref, l_ref, *, scale, bq, bk,
                nk):
    jk = pl.program_id(2)
    iq = pl.program_id(1)

    @pl.when(jk == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)
        lse_ref[...] = jnp.full_like(lse_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    q = q_ref[0]                     # (bq, d)
    k = k_ref[0]                     # (bk, d)
    v = v_ref[0]                     # (bk, d)
    s = jnp.dot(q, k.T) * scale      # (bq, bk)

    qpos = iq * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
    kpos = jk * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
    s = jnp.where(kpos <= qpos, s, NEG_INF)

    m_prev = lse_ref[0]              # (bq,) running max (lse at the end)
    l_prev = l_ref[0]
    o_prev = o_ref[0]

    m_cur = jnp.maximum(m_prev, s.max(axis=-1))
    alpha = jnp.exp(m_prev - m_cur)
    p = jnp.exp(s - m_cur[:, None])
    l_cur = alpha * l_prev + p.sum(axis=-1)
    o_cur = alpha[:, None] * o_prev + jnp.dot(p, v)

    o_ref[0] = o_cur
    lse_ref[0] = m_cur
    l_ref[0] = l_cur

    @pl.when(jk == nk - 1)
    def _finalize():
        o_ref[0] = o_cur / l_cur[:, None]
        lse_ref[0] = m_cur + jnp.log(l_cur)


def _fwd(q, k, v):
    """q, k, v: (BH, S, D) -> (o, lse) with o: (BH, S, D), lse: (BH, S)."""
    bh, s, d = q.shape
    bq = pick_block(s, 128)
    bk = pick_block(s, 128)
    nq, nk = s // bq, s // bk
    scale = 1.0 / (d ** 0.5)
    kern = functools.partial(_fwd_kernel, scale=scale, bq=bq, bk=bk, nk=nk)
    o, lse, _ = pl.pallas_call(
        kern,
        grid=(bh, nq, nk),
        in_specs=[
            pl.BlockSpec((1, bq, d), lambda b, i, j: (b, i, 0)),
            pl.BlockSpec((1, bk, d), lambda b, i, j: (b, j, 0)),
            pl.BlockSpec((1, bk, d), lambda b, i, j: (b, j, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, bq, d), lambda b, i, j: (b, i, 0)),
            pl.BlockSpec((1, bq), lambda b, i, j: (b, i)),
            pl.BlockSpec((1, bq), lambda b, i, j: (b, i)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((bh, s, d), q.dtype),
            jax.ShapeDtypeStruct((bh, s), jnp.float32),
            jax.ShapeDtypeStruct((bh, s), jnp.float32),
        ],
        interpret=INTERPRET,
    )(q, k, v)
    return o, lse


def _bwd_kernel(q_ref, k_ref, v_ref, o_ref, lse_ref, do_ref, dq_ref, dk_ref,
                dv_ref, *, scale, s):
    q = q_ref[0]
    k = k_ref[0]
    v = v_ref[0]
    o = o_ref[0]
    lse = lse_ref[0]
    do = do_ref[0]

    logits = jnp.dot(q, k.T) * scale               # (s, s)
    qpos = jax.lax.broadcasted_iota(jnp.int32, (s, s), 0)
    kpos = jax.lax.broadcasted_iota(jnp.int32, (s, s), 1)
    mask = kpos <= qpos
    p = jnp.where(mask, jnp.exp(logits - lse[:, None]), 0.0)

    dv_ref[0] = jnp.dot(p.T, do)
    dp = jnp.dot(do, v.T)
    delta = (do * o).sum(axis=-1)                  # (s,)
    ds = p * (dp - delta[:, None]) * scale
    dq_ref[0] = jnp.dot(ds, k)
    dk_ref[0] = jnp.dot(ds.T, q)


def _bwd(res, do):
    q, k, v, o, lse = res
    bh, s, d = q.shape
    kern = functools.partial(_bwd_kernel, scale=1.0 / (d ** 0.5), s=s)
    spec3 = pl.BlockSpec((1, s, d), lambda b: (b, 0, 0))
    spec2 = pl.BlockSpec((1, s), lambda b: (b, 0))
    dq, dk, dv = pl.pallas_call(
        kern,
        grid=(bh,),
        in_specs=[spec3, spec3, spec3, spec3, spec2, spec3],
        out_specs=[spec3, spec3, spec3],
        out_shape=[jax.ShapeDtypeStruct((bh, s, d), q.dtype)] * 3,
        interpret=INTERPRET,
    )(q, k, v, o, lse, do)
    return dq, dk, dv


@jax.custom_vjp
def _flash_bhsd(q, k, v):
    return _fwd(q, k, v)[0]


def _flash_fwd_rule(q, k, v):
    o, lse = _fwd(q, k, v)
    return o, (q, k, v, o, lse)


_flash_bhsd.defvjp(_flash_fwd_rule, _bwd)


def flash_attention(q, k, v):
    """Causal flash attention. q, k, v: (B, S, H, D) -> (B, S, H, D)."""
    b, s, h, d = q.shape

    def to_bhsd(t):
        return t.transpose(0, 2, 1, 3).reshape(b * h, s, d)

    o = _flash_bhsd(to_bhsd(q), to_bhsd(k), to_bhsd(v))
    return o.reshape(b, h, s, d).transpose(0, 2, 1, 3)
