"""Pure-jnp oracles for the Pallas kernels.

Every Pallas kernel in this package is validated against these references by
``python/tests/test_kernels.py`` (hypothesis sweeps shapes) — this is the
core L1 correctness signal. The references are also the fallback compute
path when a config sets ``use_pallas=False``.
"""

import jax
import jax.numpy as jnp


def causal_attention(q, k, v):
    """Reference multi-head causal attention.

    q, k, v: (B, S, H, D) — batch, sequence, heads, head_dim.
    Returns (B, S, H, D).
    """
    _, s, _, d = q.shape
    scale = 1.0 / jnp.sqrt(jnp.asarray(d, q.dtype))
    logits = jnp.einsum("bqhd,bkhd->bhqk", q, k) * scale
    mask = jnp.tril(jnp.ones((s, s), bool))
    logits = jnp.where(mask[None, None], logits, -jnp.inf)
    probs = jax.nn.softmax(logits, axis=-1)
    return jnp.einsum("bhqk,bkhd->bqhd", probs, v)


def exit_loss(x, w_out, targets, valid):
    """Reference fused unembed + softmax cross-entropy.

    x: (N, H) token hidden states; w_out: (H, V); targets: (N,) int32;
    valid: (N,) float32 {0,1} mask (PAD positions contribute 0).
    Returns (mean_loss, per_token_loss) where mean is over valid tokens.
    """
    logits = x @ w_out
    lse = jax.scipy.special.logsumexp(logits, axis=-1)
    correct = jnp.take_along_axis(logits, targets[:, None], axis=-1)[:, 0]
    per_token = (lse - correct) * valid
    denom = jnp.maximum(valid.sum(), 1.0)
    return per_token.sum() / denom, per_token


def layer_norm(x, gamma, beta, eps=1e-5):
    """Reference LayerNorm over the last axis. x: (..., H)."""
    mean = x.mean(axis=-1, keepdims=True)
    var = ((x - mean) ** 2).mean(axis=-1, keepdims=True)
    xhat = (x - mean) * jax.lax.rsqrt(var + eps)
    return xhat * gamma + beta
