"""Fused exit-layer loss: unembed GEMM + softmax cross-entropy, in Pallas.

This is the paper's compute/memory hot-spot (Section 3.2 / Appendix E): each
early-exit layer is dominated by an (N, H) x (H, V) unembedding whose output
logits — s*b*V floats per microbatch — dominate activation memory. Megatron
fuses the vocab-parallel cross-entropy in CUDA; the TPU re-thinking here
tiles the vocabulary axis with the Pallas grid and keeps a streaming
log-sum-exp in VMEM-resident accumulator refs, so the full logits tensor is
**never materialised** in HBM — only (bn, bv) tiles live at any time.

    forward  grid (N/bn, V/bv), vocab innermost:
        m, l, c accumulate running max / normaliser / correct-logit
        loss_t = (m + log l - c) * valid_t         (emitted at last tile)
    backward (two kernels, mirroring the forward tiling):
        dX  grid (N/bn, V/bv): dX  += ((p - 1{t}) * dloss) @ W_tile^T
        dW  grid (V/bv, N/bn): dW_tile += X_blk^T @ ((p - 1{t}) * dloss)
    with p recomputed per-tile from the saved per-token LSE.

VMEM per grid step (f32): bn*h + h*bv + bn*bv. At the DESIGN.md reference
point (bn, bv) = (128, 512), h = 1024 this is ~2.9 MiB — well inside a 16
MiB VMEM budget, with 128-multiple MXU-aligned GEMM tiles.

Validated against kernels.ref.exit_loss (loss and grads) by
python/tests/test_kernels.py.
"""

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl

from .util import INTERPRET, NEG_INF, pick_block


def _fwd_kernel(x_ref, w_ref, t_ref, valid_ref, loss_ref, lse_ref, m_ref,
                l_ref, c_ref, *, bv, nv):
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        c_ref[...] = jnp.zeros_like(c_ref)

    x = x_ref[...]                       # (bn, h)
    w = w_ref[...]                       # (h, bv)
    logits = jnp.dot(x, w)               # (bn, bv)

    bn = logits.shape[0]
    vpos = j * bv + jax.lax.broadcasted_iota(jnp.int32, (bn, bv), 1)
    hit = (vpos == t_ref[...][:, None]).astype(logits.dtype)

    m_prev, l_prev, c_prev = m_ref[...], l_ref[...], c_ref[...]
    m_cur = jnp.maximum(m_prev, logits.max(axis=-1))
    alpha = jnp.exp(m_prev - m_cur)
    l_cur = alpha * l_prev + jnp.exp(logits - m_cur[:, None]).sum(axis=-1)
    c_cur = c_prev + (logits * hit).sum(axis=-1)

    m_ref[...] = m_cur
    l_ref[...] = l_cur
    c_ref[...] = c_cur

    @pl.when(j == nv - 1)
    def _finalize():
        lse = m_cur + jnp.log(l_cur)
        lse_ref[...] = lse
        loss_ref[...] = (lse - c_cur) * valid_ref[...]


def _fwd(x, w, targets, valid):
    """x: (N, H), w: (H, V) -> (per_token_loss (N,), lse (N,))."""
    n, h = x.shape
    v = w.shape[1]
    bn = pick_block(n, 128)
    bv = pick_block(v, 512)
    nn, nv = n // bn, v // bv
    kern = functools.partial(_fwd_kernel, bv=bv, nv=nv)
    row = pl.BlockSpec((bn,), lambda i, j: (i,))
    loss, lse, _, _, _ = pl.pallas_call(
        kern,
        grid=(nn, nv),
        in_specs=[
            pl.BlockSpec((bn, h), lambda i, j: (i, 0)),
            pl.BlockSpec((h, bv), lambda i, j: (0, j)),
            row, row,
        ],
        out_specs=[row, row, row, row, row],
        out_shape=[jax.ShapeDtypeStruct((n,), jnp.float32)] * 5,
        interpret=INTERPRET,
    )(x, w, targets, valid)
    return loss, lse


def _dx_kernel(x_ref, w_ref, t_ref, lse_ref, dl_ref, dx_ref, *, bv):
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        dx_ref[...] = jnp.zeros_like(dx_ref)

    x, w = x_ref[...], w_ref[...]
    logits = jnp.dot(x, w)
    bn = logits.shape[0]
    vpos = j * bv + jax.lax.broadcasted_iota(jnp.int32, (bn, bv), 1)
    hit = (vpos == t_ref[...][:, None]).astype(logits.dtype)
    p = jnp.exp(logits - lse_ref[...][:, None])
    g = (p - hit) * dl_ref[...][:, None]
    dx_ref[...] += jnp.dot(g, w.T)


def _dw_kernel(x_ref, w_ref, t_ref, lse_ref, dl_ref, dw_ref, *, bv):
    i = pl.program_id(1)  # token-block index (innermost)
    j = pl.program_id(0)  # vocab-block index

    @pl.when(i == 0)
    def _init():
        dw_ref[...] = jnp.zeros_like(dw_ref)

    x, w = x_ref[...], w_ref[...]
    logits = jnp.dot(x, w)
    bn = logits.shape[0]
    vpos = j * bv + jax.lax.broadcasted_iota(jnp.int32, (bn, bv), 1)
    hit = (vpos == t_ref[...][:, None]).astype(logits.dtype)
    p = jnp.exp(logits - lse_ref[...][:, None])
    g = (p - hit) * dl_ref[...][:, None]
    dw_ref[...] += jnp.dot(x.T, g)


def _bwd(x, w, targets, lse, dloss):
    """dloss: (N,) cotangent of per-token loss -> (dx, dw)."""
    n, h = x.shape
    v = w.shape[1]
    bn = pick_block(n, 128)
    bv = pick_block(v, 512)
    nn, nv = n // bn, v // bv
    row = pl.BlockSpec((bn,), lambda i, j: (i,))
    dx = pl.pallas_call(
        functools.partial(_dx_kernel, bv=bv),
        grid=(nn, nv),
        in_specs=[
            pl.BlockSpec((bn, h), lambda i, j: (i, 0)),
            pl.BlockSpec((h, bv), lambda i, j: (0, j)),
            row, row, row,
        ],
        out_specs=pl.BlockSpec((bn, h), lambda i, j: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((n, h), x.dtype),
        interpret=INTERPRET,
    )(x, w, targets, lse, dloss)
    rown = pl.BlockSpec((bn,), lambda j, i: (i,))
    dw = pl.pallas_call(
        functools.partial(_dw_kernel, bv=bv),
        grid=(nv, nn),
        in_specs=[
            pl.BlockSpec((bn, h), lambda j, i: (i, 0)),
            pl.BlockSpec((h, bv), lambda j, i: (0, j)),
            rown, rown, rown,
        ],
        out_specs=pl.BlockSpec((h, bv), lambda j, i: (0, j)),
        out_shape=jax.ShapeDtypeStruct((h, v), w.dtype),
        interpret=INTERPRET,
    )(x, w, targets, lse, dloss)
    return dx, dw


@jax.custom_vjp
def exit_loss_mean(x, w, targets, valid):
    """Mean cross-entropy over valid tokens, fused unembed, no logits in HBM.

    x: (N, H); w: (H, V); targets: (N,) int32; valid: (N,) f32 mask.
    """
    loss, _ = _fwd(x, w, targets, valid)
    return loss.sum() / jnp.maximum(valid.sum(), 1.0)


def _mean_fwd(x, w, targets, valid):
    loss, lse = _fwd(x, w, targets, valid)
    denom = jnp.maximum(valid.sum(), 1.0)
    return loss.sum() / denom, (x, w, targets, valid, lse, denom)


def _mean_bwd(res, dmean):
    x, w, targets, valid, lse, denom = res
    dloss = (dmean / denom) * valid       # (N,)
    dx, dw = _bwd(x, w, targets, lse, dloss)
    dt = np.zeros(targets.shape, dtype=jax.dtypes.float0)
    return dx, dw, dt, jnp.zeros_like(valid)


exit_loss_mean.defvjp(_mean_fwd, _mean_bwd)


def exit_loss_per_token(x, w, targets, valid):
    """Per-token CE losses (no grad path) — used for validation/perplexity."""
    loss, _ = _fwd(x, w, targets, valid)
    return loss
