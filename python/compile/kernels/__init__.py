"""L1 Pallas kernels for the EE-LLM hot spots, plus pure-jnp oracles.

- ``attention.flash_attention`` — causal flash attention (training fwd/bwd).
- ``exit_loss.exit_loss_mean`` — fused unembed + streaming-LSE cross-entropy,
  the early-exit layer hot spot (never materialises the s*b*V logits).
- ``norm.layer_norm`` — fused row-wise LayerNorm.
- ``ref`` — the correctness oracles every kernel is validated against.
"""

from . import attention, exit_loss, norm, ref  # noqa: F401
