"""Build-time Python for the EE-LLM reproduction.

This package runs ONCE (``make artifacts``) to AOT-lower the model to HLO
text; it is never imported on the Rust request path.
"""
