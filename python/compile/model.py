"""L2: the early-exit GPT model in JAX, organised per pipeline stage.

The model is *never* instantiated as a monolith at run time: Rust owns the
pipeline, and each stage is a set of AOT-lowered pure functions defined
here. Parameters are flat, ordered, named lists (see ``stage_param_specs``)
so the Rust side can allocate/initialise/update them without Python.

The pipeline contract (paper Section 3.1, Eq. 2) is implemented by
``stage_aux_grads``: stage i's backward executable differentiates

    L_i^aux = sum_e w_e * CE_e(theta_i, x_in)  +  <g_out, x_out>

where ``g_out`` is an ordinary (constant) input tensor received from stage
i+1. Proposition 3.1 then guarantees d(L_i^aux)/dz = dL/dz for every tensor
z on the stage — validated numerically by python/tests/test_stages.py and
again end-to-end from Rust.

Exit placement follows Optimization 2: an exit "after layer L" reads the
hidden state entering layer L+1. Mid-stage exits are supported for
training; the decode path (inference) requires exits at stage entries,
which all presets satisfy (and which is the paper's own rule of thumb).
"""

import jax
import jax.numpy as jnp

from .configs import PAD_ID
from .kernels import ref
from .kernels.attention import flash_attention
from .kernels.exit_loss import exit_loss_mean, exit_loss_per_token
from .kernels.norm import layer_norm as pallas_layer_norm


# ---------------------------------------------------------------------------
# Parameter specs
# ---------------------------------------------------------------------------

class ParamSpec:
    """Name + shape + init recipe for one parameter tensor."""

    def __init__(self, name, shape, init, std=0.0, tie_group=None):
        self.name = name
        self.shape = tuple(shape)
        self.init = init            # "normal" | "zeros" | "ones"
        self.std = std
        self.tie_group = tie_group

    def to_json(self):
        d = {"name": self.name, "shape": list(self.shape), "init": self.init}
        if self.init == "normal":
            d["std"] = self.std
        if self.tie_group:
            d["tie_group"] = self.tie_group
        return d


def _block_specs(cfg, l):
    h, f = cfg.hidden, cfg.ffn
    std = 0.02
    # GPT-2-style scaled init for residual-writing projections.
    res_std = 0.02 / (2 * cfg.n_layers) ** 0.5
    p = f"layer{l}"
    return [
        ParamSpec(f"{p}.ln1.g", (h,), "ones"),
        ParamSpec(f"{p}.ln1.b", (h,), "zeros"),
        ParamSpec(f"{p}.attn.wqkv", (h, 3 * h), "normal", std),
        ParamSpec(f"{p}.attn.bqkv", (3 * h,), "zeros"),
        ParamSpec(f"{p}.attn.wo", (h, h), "normal", res_std),
        ParamSpec(f"{p}.attn.bo", (h,), "zeros"),
        ParamSpec(f"{p}.ln2.g", (h,), "ones"),
        ParamSpec(f"{p}.ln2.b", (h,), "zeros"),
        ParamSpec(f"{p}.mlp.w1", (h, f), "normal", std),
        ParamSpec(f"{p}.mlp.b1", (f,), "zeros"),
        ParamSpec(f"{p}.mlp.w2", (f, h), "normal", res_std),
        ParamSpec(f"{p}.mlp.b2", (h,), "zeros"),
    ]


def _head_specs(cfg, layer, kind):
    """Exit head after backbone `layer` (layer == n_layers: final exit)."""
    h, v = cfg.hidden, cfg.vocab
    p = f"exit{layer}"
    specs = []
    if kind in ("norm", "mlp"):
        specs += [ParamSpec(f"{p}.ln.g", (h,), "ones"),
                  ParamSpec(f"{p}.ln.b", (h,), "zeros")]
    if kind == "mlp":
        specs += [
            ParamSpec(f"{p}.mlp.w1", (h, cfg.ffn), "normal", 0.02),
            ParamSpec(f"{p}.mlp.b1", (cfg.ffn,), "zeros"),
            ParamSpec(f"{p}.mlp.w2", (cfg.ffn, h), "normal", 0.02),
            ParamSpec(f"{p}.mlp.b2", (h,), "zeros"),
        ]
    if cfg.tie_embeddings:
        # Tied: the head owns a (V, h) replica of the input embedding; the
        # Rust trainer all-reduces gradients across the tie group.
        specs.append(ParamSpec(f"{p}.wout", (v, h), "normal", 0.02,
                               tie_group="unembed"))
    else:
        specs.append(ParamSpec(f"{p}.wout", (h, v), "normal", 0.02))
    return specs


def stage_exits(cfg, s):
    """[(layer, head_kind, default_weight)] for stage s, final exit last."""
    out = [(e.layer, e.head, e.weight) for e in cfg.exits_of_stage(s)]
    out.sort()
    if s == cfg.pipeline_stages - 1:
        out.append((cfg.n_layers, "norm", 1.0))
    return out


def stage_param_specs(cfg, s):
    specs = []
    if s == 0:
        tie = "unembed" if cfg.tie_embeddings else None
        specs.append(ParamSpec("embed.tok", (cfg.vocab, cfg.hidden),
                               "normal", 0.02, tie_group=tie))
        specs.append(ParamSpec("embed.pos", (cfg.max_seq, cfg.hidden),
                               "normal", 0.01))
    for l in cfg.layers_of_stage(s):
        specs += _block_specs(cfg, l)
    for layer, kind, _ in stage_exits(cfg, s):
        specs += _head_specs(cfg, layer, kind)
    return specs


def params_as_dict(specs, params):
    assert len(specs) == len(params), (len(specs), len(params))
    return {sp.name: p for sp, p in zip(specs, params)}


# ---------------------------------------------------------------------------
# Forward components
# ---------------------------------------------------------------------------

def _ln(x, g, b, use_pallas):
    return pallas_layer_norm(x, g, b) if use_pallas else ref.layer_norm(x, g, b)


def _attention(q, k, v, use_pallas):
    return flash_attention(q, k, v) if use_pallas else ref.causal_attention(q, k, v)


def block_fwd(cfg, pd, l, x):
    """One pre-LN transformer block. x: (B, S, H)."""
    b, s, h = x.shape
    nh, hd = cfg.n_heads, cfg.head_dim
    p = f"layer{l}"
    up = cfg.use_pallas

    a = _ln(x, pd[f"{p}.ln1.g"], pd[f"{p}.ln1.b"], up)
    qkv = a @ pd[f"{p}.attn.wqkv"] + pd[f"{p}.attn.bqkv"]
    q, k, v = jnp.split(qkv, 3, axis=-1)
    q = q.reshape(b, s, nh, hd)
    k = k.reshape(b, s, nh, hd)
    v = v.reshape(b, s, nh, hd)
    o = _attention(q, k, v, up).reshape(b, s, h)
    x = x + o @ pd[f"{p}.attn.wo"] + pd[f"{p}.attn.bo"]

    m = _ln(x, pd[f"{p}.ln2.g"], pd[f"{p}.ln2.b"], up)
    m = jax.nn.gelu(m @ pd[f"{p}.mlp.w1"] + pd[f"{p}.mlp.b1"])
    x = x + m @ pd[f"{p}.mlp.w2"] + pd[f"{p}.mlp.b2"]
    return x


def embed_fwd(cfg, pd, tokens):
    """tokens: (B, S) int32 -> (B, S, H)."""
    s = tokens.shape[1]
    return pd["embed.tok"][tokens] + pd["embed.pos"][:s][None]


def head_logits(cfg, pd, layer, kind, x):
    """Exit head after `layer`. x: (..., H) -> logits (..., V)."""
    p = f"exit{layer}"
    up = cfg.use_pallas
    if kind in ("norm", "mlp"):
        x = _ln(x, pd[f"{p}.ln.g"], pd[f"{p}.ln.b"], up)
    if kind == "mlp":
        m = jax.nn.gelu(x @ pd[f"{p}.mlp.w1"] + pd[f"{p}.mlp.b1"])
        x = x + m @ pd[f"{p}.mlp.w2"] + pd[f"{p}.mlp.b2"]
    w = pd[f"{p}.wout"]
    if cfg.tie_embeddings:
        w = w.T
    return x @ w


def _head_pre_unembed(cfg, pd, layer, kind, x):
    """The head transform *before* the unembedding matmul (for fused CE)."""
    p = f"exit{layer}"
    up = cfg.use_pallas
    if kind in ("norm", "mlp"):
        x = _ln(x, pd[f"{p}.ln.g"], pd[f"{p}.ln.b"], up)
    if kind == "mlp":
        m = jax.nn.gelu(x @ pd[f"{p}.mlp.w1"] + pd[f"{p}.mlp.b1"])
        x = x + m @ pd[f"{p}.mlp.w2"] + pd[f"{p}.mlp.b2"]
    return x


def exit_ce(cfg, pd, layer, kind, hidden, targets):
    """Mean CE at one exit. hidden: (B, S, H); targets: (B, S) int32."""
    h = cfg.hidden
    x2 = _head_pre_unembed(cfg, pd, layer, kind, hidden).reshape(-1, h)
    t = targets.reshape(-1)
    valid = (t != PAD_ID).astype(jnp.float32)
    w = pd[f"exit{layer}.wout"]
    if cfg.tie_embeddings:
        w = w.T
    if cfg.use_pallas:
        return exit_loss_mean(x2, w, t, valid)
    return ref.exit_loss(x2, w, t, valid)[0]


def exit_ce_per_token(cfg, pd, layer, kind, hidden, targets):
    """Per-token CE at one exit (validation/perplexity; no grad path)."""
    h = cfg.hidden
    x2 = _head_pre_unembed(cfg, pd, layer, kind, hidden).reshape(-1, h)
    t = targets.reshape(-1)
    valid = (t != PAD_ID).astype(jnp.float32)
    w = pd[f"exit{layer}.wout"]
    if cfg.tie_embeddings:
        w = w.T
    if cfg.use_pallas:
        return exit_loss_per_token(x2, w, t, valid)
    return ref.exit_loss(x2, w, t, valid)[1]


# ---------------------------------------------------------------------------
# Stage-level training functions (the AOT surface)
# ---------------------------------------------------------------------------

def stage_hiddens(cfg, s, pd, x):
    """Run the stage backbone; return (x_out, {layer: hidden_after_layer}).

    ``x`` is the stage input: embedding output for stage 0, the previous
    stage's x_out otherwise. The entry hidden is recorded under the index of
    the last layer of the previous stage (0 for stage 0), which is exactly
    where Optimization-2-normalised exits read from.
    """
    layers = cfg.layers_of_stage(s)
    hiddens = {layers[0] - 1: x}
    for l in layers:
        x = block_fwd(cfg, pd, l, x)
        hiddens[l] = x
    return x, hiddens


def stage_fwd(cfg, s, params, x_or_tokens):
    """Forward step: stage input -> stage output hidden states."""
    specs = stage_param_specs(cfg, s)
    pd = params_as_dict(specs, params)
    x = embed_fwd(cfg, pd, x_or_tokens) if s == 0 else x_or_tokens
    x_out, _ = stage_hiddens(cfg, s, pd, x)
    return x_out


def _stage_losses(cfg, s, pd, x, targets):
    """All exit losses owned by stage s, on pre-computed stage input x."""
    x_out, hiddens = stage_hiddens(cfg, s, pd, x)
    losses = []
    for layer, kind, _ in stage_exits(cfg, s):
        hid = x_out if layer == cfg.n_layers else hiddens[layer]
        losses.append(exit_ce(cfg, pd, layer, kind, hid, targets))
    return x_out, losses


def stage_aux_grads(cfg, s):
    """Build the backward function for stage s (the Eq. 2 executable).

    Returns fn(params, x_in_or_tokens, targets, weights, g_out) ->
        (losses (E,), g_in (B,S,H) [absent for stage 0], *param_grads)

    ``weights`` is a length-E runtime input (E = exits on this stage,
    final exit included for the last stage) so loss-weight schedules
    (warm-up / cool-down, Appendix C.1) need no re-lowering. ``g_out`` is
    the gradient tensor received from stage s+1 (all-zeros for the last
    stage). The auxiliary term <g_out, x_out> implements Eq. (2b).
    """
    specs = stage_param_specs(cfg, s)

    def aux(params, x_or_tokens, targets, weights, g_out):
        pd = params_as_dict(specs, params)
        x = embed_fwd(cfg, pd, x_or_tokens) if s == 0 else x_or_tokens
        x_out, losses = _stage_losses(cfg, s, pd, x, targets)
        total = sum((w * l for w, l in zip(weights, losses)), jnp.float32(0))
        total = total + (g_out * x_out).sum()
        stacked = jnp.stack(losses) if losses else jnp.zeros((0,), jnp.float32)
        return total, stacked

    if s == 0:
        grad_fn = jax.grad(aux, argnums=(0,), has_aux=True)

        def bwd(params, tokens, targets, weights, g_out):
            (gparams,), losses = grad_fn(params, tokens, targets, weights,
                                         g_out)
            return (losses, *gparams)
    else:
        grad_fn = jax.grad(aux, argnums=(0, 1), has_aux=True)

        def bwd(params, x_in, targets, weights, g_out):
            (gparams, gx), losses = grad_fn(params, x_in, targets, weights,
                                            g_out)
            return (losses, gx, *gparams)

    return bwd


def stage_eval_losses(cfg, s):
    """fn(params, x_in_or_tokens, targets) -> (x_out, losses) — validation."""
    specs = stage_param_specs(cfg, s)

    def fwd(params, x_or_tokens, targets):
        pd = params_as_dict(specs, params)
        x = embed_fwd(cfg, pd, x_or_tokens) if s == 0 else x_or_tokens
        x_out, losses = _stage_losses(cfg, s, pd, x, targets)
        stacked = jnp.stack(losses) if losses else jnp.zeros((0,), jnp.float32)
        return (x_out, stacked)

    return fwd


# ---------------------------------------------------------------------------
# Monolithic reference (tests + equivalence checks only)
# ---------------------------------------------------------------------------

def full_param_specs(cfg):
    """Concatenated per-stage specs — the ordering Rust uses as well."""
    specs = []
    for s in range(cfg.pipeline_stages):
        for sp in stage_param_specs(cfg, s):
            specs.append(ParamSpec(f"s{s}.{sp.name}", sp.shape, sp.init,
                                   sp.std, sp.tie_group))
    return specs


def full_loss_fn(cfg):
    """fn(all_params, tokens, targets, weights) -> (total, losses).

    weights has one entry per exit, ordered stage-major (same order the
    per-stage weights concatenate to). Used by Rust integration tests to
    check that pipeline-parallel training computes the exact same losses
    and gradients as a single-device model (Proposition 3.1).
    """
    P = cfg.pipeline_stages
    counts = [len(stage_exits(cfg, s)) for s in range(P)]
    bounds = [sum(counts[:s]) for s in range(P)]
    sizes = [len(stage_param_specs(cfg, s)) for s in range(P)]
    offs = [sum(sizes[:s]) for s in range(P)]

    def fn(params, tokens, targets, weights):
        x = tokens
        all_losses = []
        total = 0.0
        for s in range(P):
            sp = params[offs[s]:offs[s] + sizes[s]]
            specs = stage_param_specs(cfg, s)
            pd = params_as_dict(specs, sp)
            if s == 0:
                x = embed_fwd(cfg, pd, x)
            x_next, losses = _stage_losses(cfg, s, pd, x, targets)
            for i, l in enumerate(losses):
                total = total + weights[bounds[s] + i] * l
            all_losses += losses
            x = x_next
        return total, jnp.stack(all_losses)

    return fn


def full_loss_grads_fn(cfg):
    """fn(all_params, tokens, targets, weights) -> (losses, *grads)."""
    loss_fn = full_loss_fn(cfg)
    grad_fn = jax.grad(loss_fn, argnums=0, has_aux=True)

    def fn(params, tokens, targets, weights):
        grads, losses = grad_fn(params, tokens, targets, weights)
        return (losses, *grads)

    return fn
