"""AOT bridge: lower every per-stage function to HLO text + manifest.json.

Interchange format is HLO **text**, not a serialized HloModuleProto: jax
>= 0.5 emits protos with 64-bit instruction ids which xla_extension 0.5.1
(the version behind the Rust `xla` crate) rejects; the text parser reassigns
ids and round-trips cleanly (see /opt/xla-example/README.md).

Per config the artifact set is, for each pipeline stage s:

  s{s}_fwd         (params, x|tokens)                    -> (x_out,)
  s{s}_bwd         (params, x|tokens, targets[, weights], g_out)
                   -> (losses?, g_in?, *param_grads)     [Eq. 2 executable]
  s{s}_eval        (params, x|tokens, targets)           -> (x_out[, losses])
  s{s}_adam        (step, lr, scale, *p, *g, *m, *v)     -> (*p', *m', *v')
  s{s}_sqsum       (*grads)                              -> (sq_sum,)
  s{s}_decode_w{W} (params, x|tokens, cache, pos0)       -> (x_out, cache')
  s{s}_decode_b{B}_w1
                   (params, x[B]|tokens[B], caches[B,...], pos[B])
                   -> (x_out[B], caches')  [lane-fused batched decode:
                   B independent width-1 windows, one per live session,
                   with lane-stacked KV caches and per-lane positions]
  s{s}_head{L}     (head_params, x)                      -> (logits,)
  s{s}_head{L}_b{B}
                   (head_params, x[B, H])                -> (logits[B, V],)
                   [lane-batched exit head: one dispatch decides every
                   lane in a fused group, one key per decode_lanes size]

plus, for configs with emit_reference, a monolithic `full_loss_grads` /
`full_eval` pair used by the Rust integration tests to verify that
pipeline-parallel execution reproduces single-model losses and gradients
exactly (Proposition 3.1).

Python runs ONCE at build time; the Rust binary is self-contained after
`make artifacts`.
"""

import argparse
import hashlib
import json
import os
import sys
import time

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import decode, model, optim
from .configs import param_count, presets

F32 = jnp.float32
I32 = jnp.int32


def lower_to_hlo_text(fn, *specs):
    # keep_unused=True: the Rust runtime relies on a static calling
    # convention (manifest arity == HLO entry arity), so even arguments a
    # particular stage happens not to use must stay in the signature.
    lowered = jax.jit(fn, keep_unused=True).lower(*specs)
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True)
    return comp.as_hlo_text()


def _spec(shape, dtype=F32):
    return jax.ShapeDtypeStruct(shape, dtype)


def _param_specs(specs):
    return [_spec(sp.shape) for sp in specs]


class ArtifactWriter:
    def __init__(self, out_dir):
        self.out_dir = out_dir
        self.files = {}
        os.makedirs(out_dir, exist_ok=True)

    def emit(self, name, fn, *specs):
        t0 = time.time()
        text = lower_to_hlo_text(fn, *specs)
        fname = f"{name}.hlo.txt"
        with open(os.path.join(self.out_dir, fname), "w") as f:
            f.write(text)
        digest = hashlib.sha256(text.encode()).hexdigest()[:16]
        self.files[name] = {"file": fname, "sha": digest,
                            "bytes": len(text)}
        print(f"  {name:28s} {len(text):>9d}B  {time.time()-t0:5.1f}s",
              flush=True)
        return fname


def build_config(cfg, out_root):
    print(f"[{cfg.name}] ~{param_count(cfg):,} params, "
          f"P={cfg.pipeline_stages}", flush=True)
    out_dir = os.path.join(out_root, cfg.name)
    w = ArtifactWriter(out_dir)

    b, s_len, h = cfg.microbatch, cfg.seq, cfg.hidden
    x_spec = _spec((b, s_len, h))
    tok_spec = _spec((b, s_len), I32)
    tgt_spec = _spec((b, s_len), I32)

    stages_meta = []
    for s in range(cfg.pipeline_stages):
        specs = model.stage_param_specs(cfg, s)
        pspecs = _param_specs(specs)
        exits = model.stage_exits(cfg, s)
        n_exits = len(exits)
        in_spec = tok_spec if s == 0 else x_spec
        per_stage_layers = cfg.n_layers // cfg.pipeline_stages
        cache_shape = (per_stage_layers, 2, cfg.max_seq, cfg.n_heads,
                       cfg.head_dim)

        execs = {}
        execs["fwd"] = w.emit(
            f"s{s}_fwd",
            lambda p, x, _s=s: (model.stage_fwd(cfg, _s, p, x),),
            pspecs, in_spec)

        bwd = model.stage_aux_grads(cfg, s)
        wspec = _spec((n_exits,))
        if n_exits > 0:
            execs["bwd"] = w.emit(f"s{s}_bwd", bwd, pspecs, in_spec,
                                  tgt_spec, wspec, x_spec)
        else:
            # No exits on this stage: weights input would be zero-sized;
            # lower a wrapper without it (and without the losses output).
            def bwd_noexit(p, x, t, g, _bwd=bwd):
                out = _bwd(p, x, t, jnp.zeros((0,), F32), g)
                return out[1:]  # drop empty losses
            execs["bwd"] = w.emit(f"s{s}_bwd", bwd_noexit, pspecs, in_spec,
                                  tgt_spec, x_spec)

        ev = model.stage_eval_losses(cfg, s)
        if n_exits > 0:
            execs["eval"] = w.emit(f"s{s}_eval", ev, pspecs, in_spec,
                                   tgt_spec)
        else:
            execs["eval"] = w.emit(
                f"s{s}_eval", lambda p, x, t, _ev=ev: (_ev(p, x, t)[0],),
                pspecs, in_spec, tgt_spec)

        n_p = len(specs)
        execs["adam"] = w.emit(
            f"s{s}_adam", optim.adam_step_fn(n_p),
            _spec(()), _spec(()), _spec(()),
            *(pspecs * 4))
        execs["sqsum"] = w.emit(f"s{s}_sqsum", optim.grad_sqsum_fn(n_p),
                                *pspecs)

        cache_spec = _spec(cache_shape)
        for width in sorted(set(cfg.decode_widths + [cfg.prefill_width])):
            dec = decode.stage_decode_fn(cfg, s)
            din = _spec((width,), I32) if s == 0 else _spec((width, h))
            execs[f"decode_w{width}"] = w.emit(
                f"s{s}_decode_w{width}", dec, pspecs, din, cache_spec,
                _spec((), I32))

        for lanes in sorted(set(cfg.decode_lanes)):
            dec_b = decode.stage_decode_batched_fn(cfg, s)
            din = (_spec((lanes,), I32) if s == 0
                   else _spec((lanes, h)))
            execs[f"decode_b{lanes}_w1"] = w.emit(
                f"s{s}_decode_b{lanes}_w1", dec_b, pspecs, din,
                _spec((lanes,) + cache_shape), _spec((lanes,), I32))

        exit_meta = []
        first_layer = cfg.layers_of_stage(s)[0]
        for layer, kind, weight in exits:
            head_fn, idx = decode.head_decode_fn(cfg, s, layer, kind)
            hname = f"head{layer}"
            execs[hname] = w.emit(
                f"s{s}_head{layer}", head_fn,
                [_spec(specs[i].shape) for i in idx], _spec((h,)))
            for lanes in sorted(set(cfg.decode_lanes)):
                bhead_fn, bidx = decode.head_decode_batched_fn(
                    cfg, s, layer, kind)
                assert bidx == idx
                execs[f"head{layer}_b{lanes}"] = w.emit(
                    f"s{s}_head{layer}_b{lanes}", bhead_fn,
                    [_spec(specs[i].shape) for i in bidx],
                    _spec((lanes, h)))
            exit_meta.append({
                "layer": layer,
                "head": kind,
                "weight": weight,
                "final": layer == cfg.n_layers,
                "entry": layer == first_layer - 1,
                "head_param_idx": idx,
            })

        stages_meta.append({
            "index": s,
            "n_params": n_p,
            "n_exits": n_exits,
            "params": [sp.to_json() for sp in specs],
            "exits": exit_meta,
            "cache_shape": list(cache_shape),
            "executables": execs,
        })

    reference = None
    if cfg.emit_reference:
        full_specs = model.full_param_specs(cfg)
        n_exits_total = sum(len(model.stage_exits(cfg, s))
                            for s in range(cfg.pipeline_stages))
        wspec = _spec((n_exits_total,))
        ref_lg = w.emit("full_loss_grads", model.full_loss_grads_fn(cfg),
                        _param_specs(full_specs), tok_spec, tgt_spec, wspec)
        ref_ev = w.emit("full_eval", model.full_loss_fn(cfg),
                        _param_specs(full_specs), tok_spec, tgt_spec, wspec)
        reference = {"loss_grads": ref_lg, "eval": ref_ev,
                     "n_params": len(full_specs)}

    manifest = {
        "name": cfg.name,
        "model": cfg.to_json(),
        "approx_param_count": param_count(cfg),
        "decode_widths": sorted(set(cfg.decode_widths + [cfg.prefill_width])),
        "decode_lanes": sorted(set(cfg.decode_lanes)),
        "prefill_width": cfg.prefill_width,
        "stages": stages_meta,
        "reference": reference,
        "files": w.files,
    }
    with open(os.path.join(out_dir, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=1)
    print(f"[{cfg.name}] manifest written ({len(w.files)} executables)",
          flush=True)


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--configs", default="all",
                    help="comma-separated preset names, or 'all'")
    args = ap.parse_args()

    all_cfgs = presets()
    names = (list(all_cfgs) if args.configs == "all"
             else args.configs.split(","))
    for n in names:
        if n not in all_cfgs:
            sys.exit(f"unknown config {n!r}; have {list(all_cfgs)}")
    t0 = time.time()
    for n in names:
        build_config(all_cfgs[n], args.out_dir)
    print(f"done in {time.time()-t0:.1f}s")


if __name__ == "__main__":
    main()
