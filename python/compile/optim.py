"""Adam optimizer and distributed gradient clipping, as AOT executables.

The paper trains with Adam (beta1=0.9, beta2=0.95, eps=1e-8) and Megatron's
global-gradient-norm clipping. With pipeline parallelism the global norm
spans stages, so clipping is split Megatron-style:

  1. each stage runs ``grad_sqsum`` over its local gradients,
  2. the Rust coordinator all-reduces the scalars and computes
     scale = min(1, clip / global_norm),
  3. each stage runs ``adam_step`` with that scale as an input.

Hyperparameters (lr, scale) are runtime inputs so schedules (cosine LR,
Appendix C.1 loss-weight schedules) live entirely in Rust.
"""

import jax.numpy as jnp

BETA1 = 0.9
BETA2 = 0.95
EPS = 1e-8


def adam_step_fn(n_params):
    """fn(step, lr, scale, *params, *grads, *m, *v) -> (*p', *m', *v').

    ``step`` is the 1-based iteration count as f32 (bias correction);
    ``scale`` multiplies gradients (gradient clipping / microbatch
    normalisation).
    """

    def fn(step, lr, scale, *tensors):
        assert len(tensors) == 4 * n_params
        params = tensors[:n_params]
        grads = tensors[n_params:2 * n_params]
        ms = tensors[2 * n_params:3 * n_params]
        vs = tensors[3 * n_params:]
        bc1 = 1.0 - BETA1 ** step
        bc2 = 1.0 - BETA2 ** step
        out_p, out_m, out_v = [], [], []
        for p, g, m, v in zip(params, grads, ms, vs):
            g = g * scale
            m2 = BETA1 * m + (1.0 - BETA1) * g
            v2 = BETA2 * v + (1.0 - BETA2) * g * g
            update = (m2 / bc1) / (jnp.sqrt(v2 / bc2) + EPS)
            out_p.append(p - lr * update)
            out_m.append(m2)
            out_v.append(v2)
        return (*out_p, *out_m, *out_v)

    return fn


def grad_sqsum_fn(n_params):
    """fn(*grads) -> (sum of squared entries,) — local half of global norm."""

    def fn(*grads):
        assert len(grads) == n_params
        total = jnp.float32(0.0)
        for g in grads:
            total = total + (g.astype(jnp.float32) ** 2).sum()
        return (total,)

    return fn
