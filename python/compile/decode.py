"""Autoregressive decode functions with KV caching, per pipeline stage.

Every decode executable processes a *window* of W consecutive tokens at
positions [pos0, pos0+W) against a static-capacity KV cache:

  - W = 1            : ordinary single-token decoding,
  - W = prefill_width: chunked prompt prefill,
  - W = recompute widths: the KV-recomputation inference method (Section 4 /
    Appendix D.3) — deficit tokens ride in the same window as the current
    token so their missing deep-layer KV entries are recomputed in one pass
    (the "batching effect" the paper leans on).

The cache layout per stage is (n_stage_layers, 2, max_seq, n_heads,
head_dim) f32. The window's K/V are scattered into the cache first; the
attention mask then admits key position kp for query position qp=pos0+j iff
kp <= qp, so stale/zero cache entries beyond the frontier are never read.

Early-exit heads are separate executables over a single hidden vector (the
current token), applied by the Rust engine at stage entries (Optimization-2
placement); see model.head_logits for the head maths.
"""

import jax
import jax.numpy as jnp

from . import model
from .kernels import ref


def _block_decode(cfg, pd, l, x, kc, vc, pos0):
    """One block over a W-token window. x: (W, H); kc/vc: (S, nh, hd)."""
    w = x.shape[0]
    nh, hd = cfg.n_heads, cfg.head_dim
    p = f"layer{l}"
    up = cfg.use_pallas

    a = model._ln(x, pd[f"{p}.ln1.g"], pd[f"{p}.ln1.b"], up)
    qkv = a @ pd[f"{p}.attn.wqkv"] + pd[f"{p}.attn.bqkv"]
    q, k, v = jnp.split(qkv, 3, axis=-1)
    q = q.reshape(w, nh, hd)
    k = k.reshape(w, nh, hd)
    v = v.reshape(w, nh, hd)

    kc = jax.lax.dynamic_update_slice(kc, k, (pos0, 0, 0))
    vc = jax.lax.dynamic_update_slice(vc, v, (pos0, 0, 0))

    s = kc.shape[0]
    scale = 1.0 / jnp.sqrt(jnp.asarray(hd, x.dtype))
    scores = jnp.einsum("whd,shd->hws", q, kc) * scale     # (nh, W, S)
    qpos = pos0 + jnp.arange(w)
    kpos = jnp.arange(s)
    mask = kpos[None, :] <= qpos[:, None]                   # (W, S)
    scores = jnp.where(mask[None], scores, -1e30)
    probs = jax.nn.softmax(scores, axis=-1)
    o = jnp.einsum("hws,shd->whd", probs, vc).reshape(w, -1)
    x = x + o @ pd[f"{p}.attn.wo"] + pd[f"{p}.attn.bo"]

    m = model._ln(x, pd[f"{p}.ln2.g"], pd[f"{p}.ln2.b"], up)
    m = jax.nn.gelu(m @ pd[f"{p}.mlp.w1"] + pd[f"{p}.mlp.b1"])
    x = x + m @ pd[f"{p}.mlp.w2"] + pd[f"{p}.mlp.b2"]
    return x, kc, vc


def stage_decode_fn(cfg, s):
    """fn(params, x_or_tokens, cache, pos0) -> (x_out, new_cache).

    Stage 0 takes tokens (W,) int32 and embeds them (token + positional at
    pos0..pos0+W-1); later stages take x (W, H). cache:
    (n_stage_layers, 2, max_seq, n_heads, head_dim).
    """
    specs = model.stage_param_specs(cfg, s)
    layers = cfg.layers_of_stage(s)

    def fn(params, x_or_tokens, cache, pos0):
        pd = model.params_as_dict(specs, params)
        if s == 0:
            pos = jax.lax.dynamic_slice(
                pd["embed.pos"], (pos0, 0),
                (x_or_tokens.shape[0], cfg.hidden))
            x = pd["embed.tok"][x_or_tokens] + pos
        else:
            x = x_or_tokens
        new_cache = []
        for i, l in enumerate(layers):
            x, kc, vc = _block_decode(cfg, pd, l, x, cache[i, 0], cache[i, 1],
                                      pos0)
            new_cache.append(jnp.stack([kc, vc]))
        return (x, jnp.stack(new_cache))

    return fn


def stage_decode_batched_fn(cfg, s):
    """fn(params, toks_or_x, caches, pos) -> (x_out, new_caches).

    The lane-fused decode step: B *independent* width-1 windows — one per
    live decode session — advanced in a single XLA call, so serving N
    concurrent requests costs one dispatch per stage instead of N. Lanes
    carry their own KV cache and position, so sessions at different
    sequence lengths share the call; the maths per lane is exactly
    `stage_decode_fn` at W = 1 (vmap over the lane axis), which is what
    makes fused and solo decoding interchangeable mid-generation.

    Stage 0 takes tokens (B,) int32; later stages take x (B, H).
    caches: (B, n_stage_layers, 2, max_seq, n_heads, head_dim);
    pos: (B,) int32 — each lane's current position.
    """
    base = stage_decode_fn(cfg, s)

    def lane(params, xt, cache, pos):
        win = xt[None] if s == 0 else xt[None, :]
        x, new_cache = base(params, win, cache, pos)
        return x[0], new_cache

    def fn(params, x_or_tokens, caches, pos):
        return jax.vmap(lane, in_axes=(None, 0, 0, 0))(
            params, x_or_tokens, caches, pos)

    return fn


def head_decode_fn(cfg, s, layer, kind):
    """fn(head_params, x (H,)) -> logits (V,) for the exit after `layer`."""
    all_specs = model.stage_param_specs(cfg, s)
    prefix = f"exit{layer}."
    idx = [i for i, sp in enumerate(all_specs) if sp.name.startswith(prefix)]
    sub_specs = [all_specs[i] for i in idx]

    def fn(head_params, x):
        pd = {sp.name: p for sp, p in zip(sub_specs, head_params)}
        logits = model.head_logits(cfg, pd, layer, kind, x[None, :])[0]
        return (logits,)

    return fn, idx


def head_decode_batched_fn(cfg, s, layer, kind):
    """fn(head_params, x (B, H)) -> logits (B, V): lane-batched exit head.

    One exit-head call for a whole fused lane group — per-lane exit
    decisions from a single XLA dispatch instead of B solo `head_decode_fn`
    calls. Each lane is exactly the solo head (vmap over the lane axis),
    so batched and solo exit decisions are interchangeable mid-generation,
    the same contract `stage_decode_batched_fn` keeps for the body.
    """
    solo, idx = head_decode_fn(cfg, s, layer, kind)

    def fn(head_params, x):
        (logits,) = jax.vmap(lambda xi: solo(head_params, xi))(x)
        return (logits,)

    return fn, idx


def head_param_indices(cfg, s, layer):
    """Stage-param indices feeding the exit head after `layer`."""
    all_specs = model.stage_param_specs(cfg, s)
    prefix = f"exit{layer}."
    return [i for i, sp in enumerate(all_specs) if sp.name.startswith(prefix)]
